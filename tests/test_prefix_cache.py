"""Persistent multi-tier prefix cache (ISSUE 8): cross-session block
reuse, content-addressed host store, partial-block tail sharing.

Layers covered:

* ``BlockAllocator`` retention units — released ref-0 prefix blocks park
  on the cached-free LRU (still matchable), adoption revives them, LRU
  reclaim order under allocation pressure, the ``retain_blocks`` cap;
* engine-level reclaim-under-pressure: a second wave of *different*
  prompts reclaims wave-1 cached blocks and stays byte-identical to a
  retention-off paged engine and to dense;
* adopt-from-host identity: a finished stream's demoted blocks serve a
  brand-new session (H2D scatter, zero live sharers) bit-for-bit;
* a hypothesis property: two sequential waves sharing a system prompt
  are byte-identical across {retention on/off} x {host dedupe on/off}
  wherever the divergence point falls (including mid-block tails).

Engines are module-scoped fixtures (jitted steps are expensive to
recompile); retained cache state deliberately persists across examples
— content addressing must never produce a false hit.
"""
import numpy as np
import pytest

import jax

from hypothesis import given, settings, strategies as st

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import BlockAllocator, CloudEngine
from repro.serving import synergy as SY

S_MAX = 256
BS = 8


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=S_MAX, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


@pytest.fixture(scope="module")
def eng_dense(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX)


@pytest.fixture(scope="module")
def eng_base(pair):
    """Retention-off paged oracle."""
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=S_MAX,
                       cache_impl="paged", block_size=BS)


@pytest.fixture(scope="module")
def eng_retain(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=S_MAX,
                       cache_impl="paged", block_size=BS,
                       retain_prefix=True)


@pytest.fixture(scope="module")
def eng_hswap(pair):
    """Retention off, content-addressed host store on: finished streams
    demote their prefix blocks to host; new sessions adopt via H2D."""
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=S_MAX,
                       cache_impl="paged", block_size=BS,
                       share_prefix=True, swap=True, host_dedupe=True)


@pytest.fixture(scope="module")
def eng_both(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=S_MAX,
                       cache_impl="paged", block_size=BS,
                       retain_prefix=True, swap=True, host_dedupe=True)


def _toks(rng, n):
    return [int(t) for t in rng.integers(1, 60, size=n)]


def _wave(common, n_streams, seed, suffix_max=12):
    rng = np.random.default_rng(seed)
    return [common + _toks(rng, int(rng.integers(1, suffix_max)))
            for _ in range(n_streams)]


# ---------------------------------------------------------------------------
# BlockAllocator retention units
# ---------------------------------------------------------------------------

def test_cached_free_retention_and_revival():
    a = BlockAllocator(8, 4, 4, 8, retain_prefix=True)
    toks = list(range(1, 13))                    # 3 full blocks
    assert a.extend(0, 12)
    a.register_prefix(0, toks)
    a.prepare_writes(0, range(3))                # realize fill-pending
    bids = [int(a.table[0, j]) for j in range(3)]
    freed = a.release(0)
    # every registered block parks on the cached-free LRU, none freed
    assert list(freed) == []
    assert a.cached_blocks == 3 and a.used_blocks == 0
    assert a.free_blocks == 5
    # still matchable across the session boundary (len-1 cap: 2 of 3)
    m = a.match_prefix(toks)
    assert m == bids[:2]
    a.adopt_prefix(1, m)                         # revives, no allocation
    assert a.revived_blocks == 2
    assert a.cached_blocks == 1 and a.used_blocks == 2
    assert all(int(a.ref[b]) == 1 for b in m)
    # releasing the adopter parks them again
    assert list(a.release(1)) == []
    assert a.cached_blocks == 3 and a.used_blocks == 0


def test_lru_reclaim_ordering_under_pressure():
    a = BlockAllocator(6, 4, 4, 8, retain_prefix=True)
    t1, t2 = list(range(1, 9)), list(range(21, 29))   # 2 blocks each
    assert a.extend(0, 8)
    a.register_prefix(0, t1)
    a.prepare_writes(0, range(2))
    assert a.extend(1, 8)
    a.register_prefix(1, t2)
    a.prepare_writes(1, range(2))
    bids1 = [int(a.table[0, j]) for j in range(2)]
    a.release(0)                                 # parked first = LRU end
    a.release(1)
    assert a.cached_blocks == 4 and a.free_blocks == 2
    assert a.allocatable_blocks() == 6
    # pressure: 4 blocks needed, only 2 truly free -> reclaim exactly
    # the 2 least-recently-parked blocks (slot 0's), in park order
    assert a.extend(2, 16)
    assert a.reclaimed_blocks == 2
    assert a.take_reclaimed() == bids1
    assert a.take_reclaimed() == []              # drained
    # the reclaimed chain is gone from the index; the younger survives
    assert a.match_prefix(t1) == []
    assert len(a.match_prefix(t2)) == 1
    assert a.cached_blocks == 2 and a.used_blocks == 4


def test_retain_blocks_cap_evicts_lru():
    a = BlockAllocator(8, 4, 4, 8, retain_prefix=True, retain_blocks=2)
    toks = list(range(1, 13))
    assert a.extend(0, 12)
    a.register_prefix(0, toks)
    a.prepare_writes(0, range(3))
    bids = [int(a.table[0, j]) for j in range(3)]
    freed = a.release(0)
    # cap 2: the least-recently-parked block spills to the free list
    # (and is returned for invalidation)
    assert list(freed) == [bids[0]]
    assert a.cached_blocks == 2
    assert a.match_prefix(toks) == []            # chain broke at block 0
    assert a.match_prefix(toks[:1]) == []


# ---------------------------------------------------------------------------
# Engine-level: reclaim under pressure, adopt from host
# ---------------------------------------------------------------------------

def test_reclaim_under_pressure_identity(dev, eng_dense, pair):
    """Retention on a tight pool: wave 2 with *different* prompts must
    reclaim wave-1 cached blocks, and both waves stay byte-identical to
    a retention-off paged engine and to dense."""
    _, _, llm_cfg, llm_p = pair
    mk = dict(max_slots=2, s_max=S_MAX, cache_impl="paged",
              block_size=4, pool_blocks=14)
    eng_r = CloudEngine(llm_cfg, llm_p, retain_prefix=True, **mk)
    eng_p = CloudEngine(llm_cfg, llm_p, **mk)
    w1 = _wave(_toks(np.random.default_rng(101), 8), 2, seed=7)
    w2 = _wave(_toks(np.random.default_rng(202), 8), 2, seed=9)
    for wave in (w1, w2):
        r_ref = SY.run_synera(dev, eng_dense, wave, 8, concurrency=1)
        r_p = SY.run_synera(dev, eng_p, wave, 8, concurrency=2)
        r_r = SY.run_synera(dev, eng_r, wave, 8, concurrency=2)
        assert r_p.outputs == r_ref.outputs
        assert r_r.outputs == r_ref.outputs
    a = eng_r.allocator
    assert a.reclaimed_blocks > 0, dict(eng_r.pool_stats)
    assert a.used_blocks == 0


def test_adopt_from_host_identity(dev, eng_base, eng_hswap):
    """A finished stream's demoted blocks serve a brand-new session:
    wave 2 adopts from the content-addressed host store (zero live
    sharers) and stays bit-identical to the non-caching paged engine."""
    common = _toks(np.random.default_rng(303), 3 * BS)
    w1 = _wave(common, 2, seed=11)
    w2 = _wave(common, 2, seed=13)               # fresh suffixes
    r1_ref = SY.run_synera(dev, eng_base, w1, 8, concurrency=2)
    r1 = SY.run_synera(dev, eng_hswap, w1, 8, concurrency=2)
    assert r1.outputs == r1_ref.outputs
    sm = eng_hswap.swap_manager
    # wave 1 finished: its prefix chain was demoted, nobody shares it
    assert sm.host_store_blocks > 0
    assert sm.host_lru_blocks == sm.host_store_blocks
    assert eng_hswap.allocator.used_blocks == 0
    r2_ref = SY.run_synera(dev, eng_base, w2, 8, concurrency=2)
    r2 = SY.run_synera(dev, eng_hswap, w2, 8, concurrency=2)
    assert r2.outputs == r2_ref.outputs
    assert sm.host_adopted_blocks > 0, dict(eng_hswap.pool_stats)
    assert sm.adopt_in_bytes > 0


# ---------------------------------------------------------------------------
# Property: identity across the retention x host-dedupe matrix
# ---------------------------------------------------------------------------

@given(st.integers(4, 20),        # common prefix length (mid-block tails)
       st.integers(2, 3),         # streams per wave
       st.integers(1, 11))        # wave seed
@settings(max_examples=3, deadline=None)
def test_persistent_cache_identity_matrix(dev, eng_base, eng_retain,
                                          eng_hswap, eng_both,
                                          common_len, n_streams, seed):
    """Two sequential waves sharing a system prompt are byte-identical
    across {retention on/off} x {host dedupe on/off}, wherever the
    divergence point falls relative to block boundaries."""
    rng = np.random.default_rng(common_len * 37 + seed)
    common = _toks(rng, common_len)
    waves = [_wave(common, n_streams, seed=seed + k) for k in range(2)]
    for wave in waves:
        ref = SY.run_synera(dev, eng_base, wave, 8,
                            concurrency=n_streams).outputs
        for eng in (eng_retain, eng_hswap, eng_both):
            got = SY.run_synera(dev, eng, wave, 8,
                                concurrency=n_streams).outputs
            assert got == ref, dict(eng.pool_stats)
            assert eng.allocator.used_blocks == 0
