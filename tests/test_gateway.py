"""Gateway tests: OpenAI wire framing, RealClock semantics, streaming
identity over a real socket, cancellation/disconnect resource release,
and 429 backpressure at the queue cap.

The HTTP tests run a real ``Gateway`` (engine thread + asyncio thread)
on an ephemeral port and talk to it with plain blocking sockets — the
container has no HTTP client library, and raw sockets double as the
strictest check of the SSE byte framing.
"""
import json
import socket
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.gateway import protocol as P
from repro.serving.link import RealClock
from repro.serving.server import SyneraServer
from repro.serving import synergy as SY


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


@pytest.fixture(scope="module")
def eng4(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=256)


@pytest.fixture(scope="module")
def eng_paged(pair):
    """Paged engine with prefix sharing + the host swap tier enabled —
    the cancel/disconnect tests must show teardown leaks nothing even
    with shared and swappable blocks in play."""
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256,
                       cache_impl="paged", block_size=16,
                       share_prefix=True, swap=True)


def _prompts(n, length=8):
    rng = np.random.default_rng(5)
    return [[int(t) for t in rng.integers(1, 60, size=length)]
            for _ in range(n)]


# ---------------------------------------------------------------------
# plain-socket HTTP client helpers
# ---------------------------------------------------------------------

def _parse_response(data: bytes):
    head, _, body = data.partition(b"\r\n\r\n")
    lines = head.decode("latin1").split("\r\n")
    status = int(lines[0].split()[1])
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, body


def _raw_request(port, method, path, obj=None, timeout=180):
    payload = json.dumps(obj).encode() if obj is not None else b""
    head = [f"{method} {path} HTTP/1.1", "Host: localhost",
            "Connection: close"]
    if payload:
        head += ["Content-Type: application/json",
                 f"Content-Length: {len(payload)}"]
    sock = socket.create_connection(("127.0.0.1", port), timeout=timeout)
    try:
        sock.sendall(("\r\n".join(head) + "\r\n\r\n").encode() + payload)
        data = b""
        while True:
            chunk = sock.recv(65536)
            if not chunk:
                break
            data += chunk
    finally:
        sock.close()
    return _parse_response(data)


def _recv_response(sock):
    """Read exactly one Content-Length-delimited response (keep-alive
    safe: does not rely on EOF to find the end of the body)."""
    data = b""
    while b"\r\n\r\n" not in data:
        chunk = sock.recv(65536)
        if not chunk:
            raise ConnectionError("EOF before response head")
        data += chunk
    head, _, body = data.partition(b"\r\n\r\n")
    status, headers, _ = _parse_response(head + b"\r\n\r\n")
    clen = int(headers.get("content-length", "0"))
    while len(body) < clen:
        chunk = sock.recv(65536)
        if not chunk:
            break
        body += chunk
    return status, headers, body[:clen]


def _sse_frames(body: bytes):
    """Split an SSE body into its ``data:`` payloads (bytes)."""
    out = []
    for frame in body.split(b"\n\n"):
        if frame.startswith(b"data: "):
            out.append(frame[len(b"data: "):])
    return out


def _chat_body(prompt, max_new, stream=True):
    return {"model": "synera-tiny", "stream": stream,
            "max_tokens": max_new,
            "messages": [{"role": "user",
                          "content": " ".join(str(t) for t in prompt)}]}


def _start_gateway(dev, eng, **cfg_kw):
    server = SyneraServer(dev, eng, clock=RealClock(),
                          clamp_arrivals=True)
    gw = Gateway(server, GatewayConfig(port=0, **cfg_kw)).start()
    return gw, server


# ---------------------------------------------------------------------
# units: clock + wire framing (no sockets, no model)
# ---------------------------------------------------------------------

def test_realclock_semantics():
    c = RealClock()                      # unpaced: never sleeps
    t0 = c.now_ms
    c.advance(500.0)
    assert c.modeled_ms == 500.0
    assert c.now_ms - t0 < 250           # did not sleep 500ms of real time
    c.advance_to(900.0)
    assert c.modeled_ms == 900.0
    c.advance_to(100.0)                  # never moves modeled time backwards
    assert c.modeled_ms == 900.0
    assert c.now_ms >= t0                # real time is monotonic

    p = RealClock(pace=True)
    t0 = p.now_ms
    p.advance(30.0)
    assert p.now_ms - t0 >= 25           # paced: slept through modeled cost
    assert p.modeled_ms == 30.0


def test_parse_chat_request_validation():
    kw = dict(default_model="m", default_max_tokens=8, max_tokens_cap=16)
    req = P.parse_chat_request(json.dumps({
        "messages": [{"role": "user", "content": "3 5 7"}],
        "stream": True, "max_tokens": 99}).encode(), **kw)
    assert req.prompt == [3, 5, 7]
    assert req.max_tokens == 16          # clamped to the cap
    assert req.stream and req.include_usage

    ok = {"messages": [{"role": "user", "content": "3 5"}]}
    assert P.parse_chat_request(json.dumps(ok).encode(), **kw).max_tokens == 8

    for bad in [b"not json", b"[]",
                json.dumps({"messages": []}).encode(),
                json.dumps({"messages": [{"role": "u"}]}).encode(),
                json.dumps({"messages": [{"content": "hello world"}]}
                           ).encode(),      # non-integer tokens
                json.dumps({"messages": [{"content": "7"}]}).encode(),
                json.dumps({"messages": [{"content": "3 5"}],
                            "max_tokens": 0}).encode()]:
        with pytest.raises(P.ProtocolError):
            P.parse_chat_request(bad, **kw)

    off = dict(ok, stream_options={"include_usage": False})
    assert not P.parse_chat_request(
        json.dumps(off).encode(), **kw).include_usage


def test_sse_framing_units():
    ev = P.sse_event(P.chunk_dict("cid", 1, "m", content=P.detok([4, 9])))
    assert ev.startswith(b"data: ") and ev.endswith(b"\n\n")
    obj = json.loads(ev[len(b"data: "):])
    assert obj["object"] == "chat.completion.chunk"
    assert obj["choices"][0]["delta"]["content"] == "4 9 "
    assert obj["choices"][0]["finish_reason"] is None

    final = P.chunk_dict("cid", 1, "m", finish_reason="length",
                         usage=P.usage_dict(3, 5))
    assert final["choices"][0]["delta"] == {}
    assert final["usage"]["total_tokens"] == 8

    assert P.parse_tokens(P.detok([1, 22, 63])) == [1, 22, 63]

    text = P.metrics_text({"queue_depth": 2, "swap": True, "clock": "wall"})
    assert "synera_queue_depth 2" in text
    assert "synera_swap 1" in text
    assert "# synera_clock: wall" in text


# ---------------------------------------------------------------------
# streaming identity over a real socket
# ---------------------------------------------------------------------

def test_stream_identity_over_socket(dev, eng4):
    """Acceptance: tokens streamed over HTTP are byte-identical to the
    in-process run_synera outputs — same prompts, same greedy pipeline —
    with correct chunk ordering, usage accounting and [DONE]."""
    prompts = _prompts(3)
    max_new = 12
    ref = SY.run_synera(dev, eng4, prompts, max_new, concurrency=1)

    gw, server = _start_gateway(dev, eng4, max_active=4, queue_cap=4)
    try:
        for i, prompt in enumerate(prompts):
            status, headers, body = _raw_request(
                gw.port, "POST", "/v1/chat/completions",
                _chat_body(prompt, max_new))
            assert status == 200
            assert headers["content-type"].startswith("text/event-stream")
            frames = _sse_frames(body)
            assert frames[-1] == b"[DONE]"
            chunks = [json.loads(f) for f in frames[:-1]]
            # one completion id, ordered roles: role delta, content
            # deltas, then the finish frame
            assert len({c["id"] for c in chunks}) == 1
            assert chunks[0]["choices"][0]["delta"] == {"role": "assistant"}
            *mid, last = chunks[1:]
            assert all(c["choices"][0]["finish_reason"] is None
                       for c in chunks[:-1])
            text = "".join(c["choices"][0]["delta"]["content"] for c in mid)
            assert P.parse_tokens(text) == list(ref.outputs[i])
            assert last["choices"][0]["delta"] == {}
            assert last["choices"][0]["finish_reason"] == "length"
            assert last["usage"] == {"prompt_tokens": len(prompt),
                                     "completion_tokens": max_new,
                                     "total_tokens": len(prompt) + max_new}
        st = server.stats()
        assert st["clock"] == "wall"
        assert st["completed_streams"] == len(prompts)
        assert st["cancelled_streams"] == 0
        assert st["ttft_ms_p50"] > 0 and st["e2e_ms_p95"] > 0
    finally:
        gw.close()


def test_non_streaming_matches_streaming(dev, eng4):
    prompt = _prompts(1)[0]
    ref = SY.run_synera(dev, eng4, [prompt], 8, concurrency=1)
    gw, _ = _start_gateway(dev, eng4, max_active=2, queue_cap=2)
    try:
        status, _, body = _raw_request(
            gw.port, "POST", "/v1/chat/completions",
            _chat_body(prompt, 8, stream=False))
        assert status == 200
        obj = json.loads(body)
        assert obj["object"] == "chat.completion"
        content = obj["choices"][0]["message"]["content"]
        assert P.parse_tokens(content) == list(ref.outputs[0])
        assert obj["usage"]["completion_tokens"] == 8

        status, _, body = _raw_request(gw.port, "POST",
                                       "/v1/chat/completions",
                                       {"messages": "nope"})
        assert status == 400
    finally:
        gw.close()


# ---------------------------------------------------------------------
# cancellation / disconnect: nothing leaks
# ---------------------------------------------------------------------

def test_cancel_releases_everything(dev, eng_paged):
    """Cancelling a mid-flight stream whose blocks are shared (prefix
    dedupe) on a swap-enabled paged engine leaks nothing: the block pool
    returns to its empty baseline, every slot is back in the free list,
    and no dead requests remain queued."""
    server = SyneraServer(dev, eng_paged)
    common = list(range(1, 17))          # one full shared prompt block
    prompts = [common + p for p in _prompts(3, length=4)]
    sessions = [server.open_session(p, 16) for p in prompts]
    server.step()
    server.step()
    victim = sessions[1]
    assert not victim.done
    assert server.cancel(victim) is True
    assert server.cancel(victim) is False          # idempotent
    assert victim.cancelled and victim.metrics is None
    while server.step():
        pass

    pool = eng_paged.pool_stats
    assert pool["used_blocks"] == 0
    # retained ref-0 prefix blocks are reusable supply, not a leak
    assert (pool["free_blocks"] + pool["cached_free_blocks"]
            == pool["n_blocks"])
    assert pool["shared_blocks"] == 0
    assert pool["swapped_blocks"] == 0
    assert sorted(server.sched.free_slots) == list(
        range(eng_paged.max_slots))
    assert not server.sched.prefill_q
    assert not server.sched.verify_q
    assert not server.sched.active_verify
    assert server._by_req == {}
    st = server.stats()
    assert st["cancelled_streams"] == 1
    assert st["completed_streams"] == 2
    # survivors still produced their full completions
    for s in (sessions[0], sessions[2]):
        assert len(s.metrics.tokens) == 16


def test_keep_alive_connection_reuse(dev, eng4):
    """HTTP/1.1 keep-alive: one connection carries several exchanges
    (health check + two full chat completions), and ``Connection:
    close`` from the client ends the session."""
    gw, _server = _start_gateway(dev, eng4)
    try:
        sock = socket.create_connection(("127.0.0.1", gw.port),
                                        timeout=180)
        try:
            def send(path, obj=None, close=False, method="GET"):
                payload = (json.dumps(obj).encode()
                           if obj is not None else b"")
                head = [f"{method} {path} HTTP/1.1", "Host: t"]
                if close:
                    head.append("Connection: close")
                if payload:
                    head += ["Content-Type: application/json",
                             f"Content-Length: {len(payload)}"]
                sock.sendall(("\r\n".join(head) + "\r\n\r\n").encode()
                             + payload)

            send("/healthz")            # no Connection header: 1.1 default
            status, headers, _ = _recv_response(sock)
            assert status == 200
            assert headers["connection"] == "keep-alive"
            for p in _prompts(2, length=6):   # chats on the same socket
                send("/v1/chat/completions",
                     _chat_body(p, 4, stream=False), method="POST")
                status, headers, body = _recv_response(sock)
                assert status == 200
                assert headers["connection"] == "keep-alive"
                choice = json.loads(body)["choices"][0]
                assert choice["finish_reason"] in ("stop", "length")
            send("/healthz", close=True)
            status, headers, _ = _recv_response(sock)
            assert status == 200
            assert headers["connection"] == "close"
            assert sock.recv(1) == b""   # server closed the connection
        finally:
            sock.close()
    finally:
        gw.close()


def test_socket_disconnect_frees_resources(dev, eng_paged):
    """A client that drops its connection mid-stream triggers a cancel
    through the gateway: the session is torn down and its blocks/slot
    are released (polled via pool_stats, the leak baseline)."""
    gw, server = _start_gateway(dev, eng_paged, max_active=2, queue_cap=2)
    try:
        prompt = _prompts(1, length=8)[0]
        sock = socket.create_connection(("127.0.0.1", gw.port),
                                        timeout=120)
        body = json.dumps(_chat_body(prompt, 64)).encode()
        sock.sendall((f"POST /v1/chat/completions HTTP/1.1\r\n"
                      f"Host: t\r\nContent-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n"
                      ).encode() + body)
        got = b""
        while b"\n\n" not in got.partition(b"\r\n\r\n")[2]:
            got += sock.recv(4096)      # at least the role chunk arrived
        sock.close()                     # hang up mid-stream

        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if (server.stats()["cancelled_streams"] >= 1
                    and eng_paged.pool_stats["used_blocks"] == 0):
                break
            time.sleep(0.05)
        st = server.stats()
        assert st["cancelled_streams"] == 1
        pool = eng_paged.pool_stats
        assert pool["used_blocks"] == 0
        assert (pool["free_blocks"] + pool["cached_free_blocks"]
                == pool["n_blocks"])
        assert pool["swapped_blocks"] == 0
        assert sorted(server.sched.free_slots) == list(
            range(eng_paged.max_slots))
    finally:
        gw.close()


# ---------------------------------------------------------------------
# backpressure + observability endpoints
# ---------------------------------------------------------------------

def test_backpressure_429_at_queue_cap(dev, eng4):
    """With max_active=1 and queue_cap=1, a 6-way concurrent burst gets
    at least one 429 (with Retry-After) and every accepted stream still
    completes with a full, correct token stream."""
    prompts = _prompts(6, length=6)
    gw, server = _start_gateway(dev, eng4, max_active=1, queue_cap=1)
    results = [None] * len(prompts)

    def _one(i):
        results[i] = _raw_request(gw.port, "POST", "/v1/chat/completions",
                                  _chat_body(prompts[i], 8))

    try:
        threads = [threading.Thread(target=_one, args=(i,))
                   for i in range(len(prompts))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        statuses = [r[0] for r in results]
        assert statuses.count(429) >= 1
        assert statuses.count(200) >= 2          # saturated, not bricked
        for status, headers, body in results:
            if status == 429:
                assert "retry-after" in headers
                assert json.loads(body)["error"]["type"] == \
                    "rate_limit_error"
            else:
                frames = _sse_frames(body)
                assert frames[-1] == b"[DONE]"
                toks = P.parse_tokens("".join(
                    json.loads(f)["choices"][0]["delta"].get("content", "")
                    for f in frames[:-1]))
                assert len(toks) == 8
        st = server.stats()
        assert st["rejected_requests"] == statuses.count(429)
        assert st["completed_streams"] == statuses.count(200)
    finally:
        gw.close()


def test_observability_endpoints(dev, eng4):
    gw, server = _start_gateway(dev, eng4, max_active=2, queue_cap=2)
    try:
        status, _, body = _raw_request(gw.port, "GET", "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"

        status, _, body = _raw_request(gw.port, "GET", "/v1/models")
        assert status == 200
        assert json.loads(body)["data"][0]["id"] == "synera-tiny"

        # one request so the counters are nonzero, then both /metrics
        # views must agree with the server's own stats()
        _raw_request(gw.port, "POST", "/v1/chat/completions",
                     _chat_body(_prompts(1)[0], 4))
        deadline = time.monotonic() + 60
        while (time.monotonic() < deadline
               and server.stats()["completed_streams"] < 1):
            time.sleep(0.05)

        status, headers, body = _raw_request(
            gw.port, "GET", "/metrics?format=json")
        assert status == 200
        js = json.loads(body)
        direct = server.stats()
        assert set(direct) <= set(js)        # + gateway_active/queued
        for k in ("completed_streams", "rejected_requests",
                  "cancelled_streams", "iterations"):
            assert js[k] == direct[k]
        assert js["clock"] == "wall"
        assert js["modeled_ms"] > 0          # shadow modeled time advanced
        assert js["gateway_active"] == 0

        status, headers, body = _raw_request(gw.port, "GET", "/metrics")
        assert status == 200
        assert headers["content-type"].startswith("text/plain")
        assert b"synera_completed_streams" in body
        assert b"synera_queue_depth" in body

        status, _, _ = _raw_request(gw.port, "GET", "/nope")
        assert status == 404
        status, _, _ = _raw_request(gw.port, "GET", "/v1/chat/completions")
        assert status == 405
    finally:
        gw.close()

def _send_chunked(sock, path, obj, chunk_size=16, trailer=True):
    """POST ``obj`` as a Transfer-Encoding: chunked body split into
    fixed-size frames, ending with a zero chunk and an optional
    trailer section."""
    payload = json.dumps(obj).encode()
    head = [f"POST {path} HTTP/1.1", "Host: t",
            "Content-Type: application/json",
            "Transfer-Encoding: chunked"]
    sock.sendall(("\r\n".join(head) + "\r\n\r\n").encode())
    for i in range(0, len(payload), chunk_size):
        frame = payload[i:i + chunk_size]
        sock.sendall(f"{len(frame):x};ext=ignored\r\n".encode()
                     + frame + b"\r\n")
    tail = b"0\r\n"
    tail += b"X-Trailer: done\r\n\r\n" if trailer else b"\r\n"
    sock.sendall(tail)


def test_chunked_request_body_keep_alive(dev, eng4):
    """A chunked-encoded chat request over a keep-alive connection is
    decoded to the same body a Content-Length request would carry: the
    deterministic server returns byte-identical completions for both
    framings on the same socket."""
    gw, _server = _start_gateway(dev, eng4)
    try:
        prompt = _prompts(1, length=6)[0]
        body_obj = _chat_body(prompt, 4, stream=False)
        sock = socket.create_connection(("127.0.0.1", gw.port),
                                        timeout=180)
        try:
            # exchange 1: chunked framing (3+ frames plus a trailer)
            _send_chunked(sock, "/v1/chat/completions", body_obj,
                          chunk_size=16)
            status, headers, body = _recv_response(sock)
            assert status == 200
            assert headers["connection"] == "keep-alive"
            chunked_choice = json.loads(body)["choices"][0]
            assert chunked_choice["finish_reason"] in ("stop", "length")

            # exchange 2, same socket: classic Content-Length framing
            payload = json.dumps(body_obj).encode()
            sock.sendall((f"POST /v1/chat/completions HTTP/1.1\r\n"
                          f"Host: t\r\n"
                          f"Content-Type: application/json\r\n"
                          f"Content-Length: {len(payload)}\r\n\r\n"
                          ).encode() + payload)
            status, headers, body = _recv_response(sock)
            assert status == 200
            assert headers["connection"] == "keep-alive"
            plain_choice = json.loads(body)["choices"][0]
            # identical body => identical deterministic completion
            assert plain_choice["message"] == chunked_choice["message"]
        finally:
            sock.close()
    finally:
        gw.close()


def test_chunked_malformed_size_rejected(dev, eng4):
    """A garbage chunk-size line is a 400, not a hang or a crash."""
    gw, _server = _start_gateway(dev, eng4)
    try:
        sock = socket.create_connection(("127.0.0.1", gw.port),
                                        timeout=180)
        try:
            sock.sendall(b"POST /v1/chat/completions HTTP/1.1\r\n"
                         b"Host: t\r\n"
                         b"Transfer-Encoding: chunked\r\n\r\n"
                         b"zz\r\n")
            status, _, body = _recv_response(sock)
            assert status == 400
            assert b"chunk size" in body
        finally:
            sock.close()
        # the listener survives: a well-formed request still succeeds
        status, _, body = _raw_request(gw.port, "GET", "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"
    finally:
        gw.close()
