"""Logits-free serving hot path: fused on-device verification must be
indistinguishable (greedy: byte-identical; sample: decision-identical
given the same rng and full support) from the PR-1 host-numpy path,
while moving orders of magnitude fewer bytes to the host.

Also covers the satellite fixes that ride along: the `_finish_verify`
row-shortfall edge case, the prefill bucket ladder + compile_stats, and
the Pallas attention dispatch (`attn_impl="pallas"`).
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.synera_pair import tiny_pair
from repro.core import verifier as V
from repro.models import layers as L
from repro.models import model as M
from repro.models.steps import fused_verify_epilogue
from repro.serving.engine import CloudEngine
from repro.serving.scheduler import (PrefillRequest, VerifyRequest,
                                     VerificationAwareScheduler)
from tests.test_scheduler_property import StubEngine

VOCAB = 64


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=VOCAB)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


def _drive(sched, req_id, kind, max_iters=100):
    for _ in range(max_iters):
        for ev in sched.run_iteration():
            if ev.req_id == req_id and ev.kind == kind:
                return ev
    raise AssertionError(f"request {req_id} never completed")


def _workload_results(engine, fused, sampling="greedy", seed=3):
    """Prefill + three verify rounds (first-verify shortfall, normal,
    multi-chunk) through the scheduler; returns the VerifyResults."""
    sched = VerificationAwareScheduler(engine, chunk=16, fused=fused,
                                       rng=np.random.default_rng(seed))
    rng = np.random.default_rng(seed + 1)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 9)))
    slot = _drive(sched, 1, "prefill_done").slot

    results = []
    rid = 1
    for unc_len in (0, 3, 40):          # shortfall, in-chunk, multi-chunk
        rid += 1
        unc = rng.integers(1, VOCAB, size=unc_len)
        draft = rng.integers(1, VOCAB, size=4)
        q_sparse = []
        for _ in range(4):
            idx = rng.choice(VOCAB, size=8, replace=False).astype(np.int32)
            val = rng.random(8)
            q_sparse.append((idx, (val / val.sum()).astype(np.float16)))
        sched.submit_verify(VerifyRequest(
            rid, slot, uncached=unc.astype(np.int64),
            draft=draft.astype(np.int64), q_sparse=q_sparse,
            sampling=sampling))
        results.append(_drive(sched, rid, "verify_done").result)
    return results, sched


# ---------------------------------------------------------------------------
# Tentpole: fused rows == host-numpy computation, streams byte-identical
# ---------------------------------------------------------------------------

def test_fused_rows_match_host_numpy(pair):
    """engine.feed's on-device epilogue must agree with numpy applied to
    the full logits the legacy path round-trips."""
    _, _, llm_cfg, llm_p = pair
    eng_f = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64)
    eng_l = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, VOCAB, size=(2, 8)).astype(np.int32)
    pos = np.broadcast_to(np.arange(8), (2, 8)).astype(np.int32).copy()
    targets = rng.integers(0, VOCAB, size=(2, 8)).astype(np.int32)
    targets[:, -1] = -1
    sel = np.tile(np.arange(8, dtype=np.int32), (2, 1))  # select every row

    rows = eng_f.feed(toks, pos, targets, sel)
    logits = eng_l.feed_logits(toks, pos)

    np.testing.assert_array_equal(rows.token_id, np.argmax(logits, -1))
    e = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    for b in range(2):
        for j in range(8):
            t = targets[b, j]
            want = probs[b, j, t] if t >= 0 else 0.0
            assert abs(rows.p_draft[b, j] - want) < 1e-5
            # top-k support holds the k largest probabilities
            got = set(rows.topk_idx[b, j].tolist())
            want_idx = set(np.argsort(-probs[b, j])[:rows.topk_idx.shape[-1]]
                           .tolist())
            # ties can reorder the tail; compare mass instead of ids
            assert abs(probs[b, j][list(got)].sum()
                       - probs[b, j][list(want_idx)].sum()) < 1e-5
    # the fused iteration moved fewer bytes even at this toy vocab (64);
    # the >=10x criterion is measured at production vocab by
    # benchmarks/hotpath_bench.py (fused bytes are vocab-independent)
    assert eng_l.bytes_to_host > 3 * eng_f.bytes_to_host


def test_fused_greedy_stream_byte_identical(pair):
    """Same workload through the fused scheduler and the PR-1 host-numpy
    scheduler: every verification decision (accepted counts, corrected
    tokens, bonus tokens) must be byte-identical."""
    _, _, llm_cfg, llm_p = pair
    eng_f = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=128)
    eng_l = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=128)
    res_f, _ = _workload_results(eng_f, fused=True)
    res_l, _ = _workload_results(eng_l, fused=False)
    for rf, rl in zip(res_f, res_l):
        assert rf.n_accepted == rl.n_accepted
        assert rf.tokens == rl.tokens
        assert rf.corrected == rl.corrected and rf.bonus == rl.bonus


def test_verify_sample_fused_matches_reference_decisions():
    """Seeded property test: with the full support (K = vocab), the fused
    sample verifier consumes the engine's sparse rows and reproduces the
    numpy reference's acceptance and resample decisions exactly."""
    V_, gamma = 32, 4
    epi = jax.jit(functools.partial(fused_verify_epilogue, top_k=V_))
    for seed in range(40):
        rng = np.random.default_rng(seed)
        logits = (rng.normal(size=(gamma + 1, V_)) * 2).astype(np.float32)
        draft = rng.integers(0, V_, size=gamma)
        q_sparse = []
        for t in range(gamma):
            k = int(rng.integers(2, 9))
            idx = rng.choice(V_, size=k, replace=False).astype(np.int32)
            if rng.random() < 0.7:    # draft token usually in the support
                idx[0] = draft[t]
            val = rng.random(k)
            q_sparse.append((idx, (val / val.sum()).astype(np.float16)))
        targets = np.append(draft, -1).astype(np.int32)
        sel = np.arange(gamma + 1, dtype=np.int32)

        tok, p_t, tk_i, tk_v = (np.asarray(a[0]) for a in epi(
            jnp.asarray(logits)[None], jnp.asarray(targets)[None],
            jnp.asarray(sel)[None]))
        topk_rows = [(tk_i[t], tk_v[t]) for t in range(gamma + 1)]

        ref = V.verify_sample(draft, logits, q_sparse,
                              np.random.default_rng(seed + 10_000))
        got = V.verify_sample_fused(draft, p_t[:gamma], topk_rows, q_sparse,
                                    np.random.default_rng(seed + 10_000), V_)
        assert got.n_accepted == ref.n_accepted, seed
        assert got.tokens == ref.tokens, seed


def test_sample_mode_first_verify_uses_prefill_row():
    """Sampling right after prefill (no uncached tokens): the pre-draft
    row is synthesized from the retained prefill row and the stream
    completes with a valid distribution-preserving result."""
    eng = StubEngine(max_slots=1, vocab=16)
    sched = VerificationAwareScheduler(eng, chunk=8, fused=True,
                                       rng=np.random.default_rng(0))
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 6)))
    _drive(sched, 1, "prefill_done")
    draft = np.array([3, 9], np.int64)
    q_sparse = [(np.array([3, 1], np.int32),
                 np.array([0.6, 0.4], np.float16)),
                (np.array([9, 2], np.int32),
                 np.array([0.5, 0.5], np.float16))]
    sched.submit_verify(VerifyRequest(2, 0, uncached=np.array([], np.int64),
                                      draft=draft, q_sparse=q_sparse,
                                      sampling="sample"))
    res = _drive(sched, 2, "verify_done").result
    assert 0 <= res.n_accepted <= 2
    assert all(0 <= t < 16 for t in res.tokens)


# ---------------------------------------------------------------------------
# Satellite: _finish_verify row-shortfall robustness
# ---------------------------------------------------------------------------

def test_finish_verify_multi_row_shortfall_raises():
    eng = StubEngine(max_slots=1, vocab=16)
    sched = VerificationAwareScheduler(eng, chunk=8, fused=True)
    req = VerifyRequest(7, 0, uncached=np.array([], np.int64),
                        draft=np.array([1, 2, 3], np.int64), q_sparse=None)
    req.rows = [(0, (1, 1.0, np.zeros(1, np.int32), np.ones(1, np.float32)))]
    with pytest.raises(RuntimeError, match="retained 1 rows but needs 4"):
        sched._finish_verify(req)


def test_finish_verify_shortfall_without_prefill_row_raises():
    eng = StubEngine(max_slots=1, vocab=16)
    sched = VerificationAwareScheduler(eng, chunk=8, fused=True)
    req = VerifyRequest(8, 0, uncached=np.array([], np.int64),
                        draft=np.array([1], np.int64), q_sparse=None)
    req.rows = [(0, (1, 1.0, np.zeros(1, np.int32), np.ones(1, np.float32)))]
    with pytest.raises(RuntimeError, match="no prefill was recorded"):
        sched._finish_verify(req)


# ---------------------------------------------------------------------------
# Satellite: prefill bucket ladder + compile_stats
# ---------------------------------------------------------------------------

def test_feed_bucket_ladder_bounds_specialization(pair):
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=1, s_max=256,
                      feed_buckets=(8, 16, 32))
    rng = np.random.default_rng(0)
    off = 0
    for width in (5, 9, 20, 33, 70):    # 33 and 70 exceed the cap -> split
        toks = rng.integers(1, VOCAB, size=(1, width)).astype(np.int32)
        pos = (off + np.arange(width))[None].astype(np.int32)
        rows = eng.feed(toks, pos)
        assert rows.token_id.shape == (1, eng.verify_rows_max)
        off += width
    stats = eng.compile_stats
    assert set(stats["buckets"]) <= {8, 16, 32}
    assert stats["n_specializations"] <= 3
    assert stats["calls"]["feed"] == 5


def test_multichunk_feed_matches_full_forward(pair):
    """A feed wider than the largest bucket is split into max-bucket
    chunks over the cache — logits must match the single full forward."""
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=1, s_max=128,
                      feed_buckets=(8, 16, 32))
    T = 70
    toks = np.random.default_rng(1).integers(1, VOCAB, size=(1, T)) \
        .astype(np.int32)
    pos = np.arange(T)[None].astype(np.int32)
    logits = eng.feed_logits(toks, pos)
    full, _, _, _ = M.forward(slm_cfg, slm_p, jnp.asarray(toks),
                              M.default_positions(1, T))
    np.testing.assert_allclose(logits[0], np.asarray(full[0]),
                               atol=2e-4, rtol=2e-3)


def test_multichunk_prefill_gathers_each_slots_last_row(pair):
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=2, s_max=128,
                      feed_buckets=(8, 16, 32))
    rng = np.random.default_rng(2)
    lens = (70, 20)                     # last rows land in different chunks
    C = max(lens)
    toks = np.zeros((2, C), np.int32)
    pos = np.full((2, C), -1, np.int32)
    for b, T in enumerate(lens):
        toks[b, :T] = rng.integers(1, VOCAB, size=T)
        pos[b, :T] = np.arange(T)
    last = eng.prefill(toks, pos)
    for b, T in enumerate(lens):
        full, _, _, _ = M.forward(slm_cfg, slm_p, jnp.asarray(toks[b:b+1, :T]),
                                  M.default_positions(1, T))
        np.testing.assert_allclose(last[b], np.asarray(full[0, -1]),
                                   atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# Tentpole: Pallas attention dispatch
# ---------------------------------------------------------------------------

def test_pallas_engine_matches_blocked(pair):
    """cfg.attn_impl="pallas" routes chunked verify through the
    partial_prefill kernel and T==1 decode through decode_gqa
    (interpret mode on CPU) with matching logits."""
    slm_cfg, slm_p, _, _ = pair
    eng_b = CloudEngine(slm_cfg, slm_p, max_slots=2, s_max=64)
    eng_p = CloudEngine(slm_cfg.replace(attn_impl="pallas"), slm_p,
                        max_slots=2, s_max=64)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, VOCAB, size=(2, 8)).astype(np.int32)
    pos = np.broadcast_to(np.arange(8), (2, 8)).astype(np.int32).copy()
    np.testing.assert_allclose(eng_p.feed_logits(toks, pos),
                               eng_b.feed_logits(toks, pos),
                               atol=2e-4, rtol=2e-3)
    t = np.array([[3], [5]], np.int32)
    p = np.array([[8], [8]], np.int32)
    np.testing.assert_allclose(eng_p.decode_logits(t, p),
                               eng_b.decode_logits(t, p),
                               atol=2e-4, rtol=2e-3)


def test_pallas_importance_matches_naive():
    """The attn_importance kernel (position-array interface) must agree
    with the naive path on a circular-cache shape with invalid slots."""
    B, Tq, S, nh, nkv, hd = 1, 1, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Tq, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nkv, hd))
    v = jax.random.normal(ks[2], (B, S, nkv, hd))
    kv_pos = np.full((B, S), -1, np.int32)
    kv_pos[:, :20] = np.arange(20)
    q_pos = np.full((B, Tq), 19, np.int32)
    o_n, i_n = L.attention(q, k, v, jnp.asarray(q_pos), jnp.asarray(kv_pos),
                           impl="naive", return_importance=True)
    o_p, i_p = L.attention(q, k, v, jnp.asarray(q_pos), jnp.asarray(kv_pos),
                           impl="pallas", return_importance=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_n),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(i_p), np.asarray(i_n),
                               atol=1e-4)


def test_pallas_device_runtime_stream_matches_naive(pair):
    """A DeviceRuntime configured with attn_impl="pallas" (importance via
    the fused kernel) produces the same edge-centric greedy stream."""
    from repro.serving.device import DeviceRuntime
    slm_cfg, slm_p, _, _ = pair
    dev_n = DeviceRuntime(slm_cfg, slm_p, s_max=64, gamma=2, seed=0)
    dev_p = DeviceRuntime(slm_cfg.replace(attn_impl="pallas"), slm_p,
                          s_max=64, gamma=2, seed=0)
    m_n = dev_n.generate([1, 2, 3, 4], 6, cloud=None)
    m_p = dev_p.generate([1, 2, 3, 4], 6, cloud=None)
    assert m_p.tokens == m_n.tokens
