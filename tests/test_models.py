"""Per-architecture smoke tests + serving-path consistency.

Each assigned architecture instantiates its REDUCED variant (2 layers,
d_model<=256, <=4 experts) and must:
  * run one train step on CPU with finite loss and correct shapes,
  * produce decode-with-cache logits that match the full forward
    (the fundamental serving-path invariant),
  * produce sliding-window decode that matches windowed full attention.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.models import layers as L
from repro.optim.adamw import AdamW


def aux_for(cfg, B, key):
    aux = {}
    if cfg.family == "vlm":
        aux["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.vision_dim))
    if cfg.family == "audio":
        aux["audio_frames"] = jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model))
    return aux


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_train_step(arch_setup):
    arch, cfg, params = arch_setup
    B, T = 2, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    batch.update(aux_for(cfg, B, key))
    loss, metrics = M.lm_loss(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # one optimizer step moves the loss
    opt = AdamW(lr=1e-2)
    state = opt.init(params)
    grads = jax.grad(lambda p: M.lm_loss(cfg, p, batch)[0])(params)
    new_params, state, om = opt.update(grads, state, params)
    loss2, _ = M.lm_loss(cfg, new_params, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(om["grad_norm"]) > 0


def test_forward_shapes_and_no_nan(arch_setup):
    arch, cfg, params = arch_setup
    B, T = 2, 24
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    logits, _, imp, _ = M.forward(cfg, params, toks,
                                  M.default_positions(B, T),
                                  aux_inputs=aux_for(cfg, B, key),
                                  return_importance=True)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN in logits"
    assert imp is not None and imp.shape == (B, T)
    assert bool(jnp.isfinite(imp).all())


def test_decode_matches_full_forward(arch_setup):
    arch, cfg, params = arch_setup
    B, T, extra = 2, 16, 4
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, T + extra), 0, cfg.vocab)
    aux = aux_for(cfg, B, key)
    full, _, _, _ = M.forward(cfg, params, toks,
                              M.default_positions(B, T + extra),
                              aux_inputs=aux)
    cache = M.init_cache(cfg, B, T + extra)
    lp, cache, _, _ = M.forward(cfg, params, toks[:, :T],
                                M.default_positions(B, T), cache=cache,
                                aux_inputs=aux)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, :T]),
                               atol=2e-4, rtol=2e-3)
    for t in range(T, T + extra):
        ld, cache, _, _ = M.forward(cfg, params, toks[:, t:t + 1],
                                    jnp.full((B, 1), t, jnp.int32),
                                    cache=cache)
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-3)


def test_verify_chunk_matches_full_forward(arch_setup):
    """The paper's partial prefill: a multi-token chunk over a cached
    prefix must equal the full forward at those positions."""
    arch, cfg, params = arch_setup
    B, T, C = 2, 12, 5
    key = jax.random.PRNGKey(4)
    toks = jax.random.randint(key, (B, T + C), 0, cfg.vocab)
    aux = aux_for(cfg, B, key)
    full, _, _, _ = M.forward(cfg, params, toks,
                              M.default_positions(B, T + C),
                              aux_inputs=aux)
    cache = M.init_cache(cfg, B, T + C)
    _, cache, _, _ = M.forward(cfg, params, toks[:, :T],
                               M.default_positions(B, T), cache=cache,
                               aux_inputs=aux)
    pos = jnp.broadcast_to(jnp.arange(T, T + C)[None], (B, C)).astype(jnp.int32)
    lv, _, _, _ = M.forward(cfg, params, toks[:, T:T + C], pos, cache=cache)
    np.testing.assert_allclose(np.asarray(lv), np.asarray(full[:, T:T + C]),
                               atol=2e-4, rtol=2e-3)


def test_sliding_window_decode():
    """Windowed circular-cache decode == full attention restricted to the
    window (dense arch)."""
    cfg = get_config("llama3.2-1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, W, total = 1, 8, 20
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    cache = M.init_cache(cfg, B, W)
    outs = []
    for t in range(total):
        ld, cache, _, _ = M.forward(cfg, params, toks[:, t:t + 1],
                                    jnp.full((B, 1), t, jnp.int32),
                                    cache=cache, window=W)
        outs.append(ld[:, 0])
    # reference: full forward with window mask
    ref_cfg = cfg
    full, _, _, _ = M.forward(ref_cfg, params, toks,
                              M.default_positions(B, total), window=W)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_ssd_chunked_equals_sequential():
    from repro.kernels.ssd_scan.ref import ssd_sequential_ref
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    B, Lx, H, P, N = 2, 48, 2, 8, 4
    x = jax.random.normal(ks[0], (B, Lx, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Lx, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, Lx, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, Lx, N)) * 0.5
    y1, h1 = L.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y2, h2 = ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_blocked_equals_naive_attention():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, T, nh, nkv, hd = 2, 33, 4, 2, 16
    q = jax.random.normal(ks[0], (B, T, nh, hd))
    k = jax.random.normal(ks[1], (B, T, nkv, hd))
    v = jax.random.normal(ks[2], (B, T, nkv, hd))
    pos = M.default_positions(B, T)
    o1, _ = L.naive_attention(q, k, v, pos, pos)
    o2 = L.blocked_attention(q, k, v, pos, pos, block_kv=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_param_count_sane():
    # llama3.2-1b should be ~1.2B params; qwen3-moe active << total
    c = get_config("llama3.2-1b")
    assert 1.0e9 < c.param_count() < 1.5e9
    m = get_config("qwen3-moe-235b-a22b")
    assert m.active_param_count() < 0.25 * m.param_count()
    l4 = get_config("llama4-maverick-400b-a17b")
    assert 2.5e11 < l4.param_count() < 5e11
