"""Unit + hypothesis property tests for Synera's core modules."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import compression as CP
from repro.core import early_exit as EE
from repro.core import parallel as PI
from repro.core import verifier as V
from repro.core.offload import (OffloadPolicy, importance_from_percentile,
                                p_conf, p_imp)
from repro.core.profiling import ChunkRecord, SyneraProfile, fit_profile


# ---------------------------------------------------------------------------
# Offload dispatch probabilities (the paper's equations, Fig 9)
# ---------------------------------------------------------------------------

class TestDispatchProbabilities:
    def test_p_conf_below_threshold_always_offloads(self):
        assert float(p_conf(0.3, c_th=0.7)) == 1.0
        assert float(p_conf(0.7, c_th=0.7)) == 1.0

    def test_p_conf_monotone_decreasing_above_threshold(self):
        cs = np.linspace(0.71, 1.0, 50)
        ps = np.array([float(p_conf(c, 0.7)) for c in cs])
        assert (np.diff(ps) <= 1e-9).all()
        assert ps[-1] < 0.01  # fully confident -> essentially never offload

    @given(st.floats(0.0, 1.0), st.floats(0.05, 0.95))
    @settings(max_examples=50, deadline=None)
    def test_p_conf_is_probability(self, c, c_th):
        p = float(p_conf(c, c_th))
        assert 0.0 <= p <= 1.0

    def test_p_imp_three_tiers(self):
        i_th = 0.6
        assert float(p_imp(0.1, i_th)) == 0.0         # <= i_th/2: local
        assert float(p_imp(0.95, i_th)) == 1.0        # > i_th: offload
        mid = float(p_imp(0.45, i_th))
        assert 0.0 < mid < 1.0                        # sigmoid tier

    @given(st.floats(0.0, 2.0), st.floats(0.05, 1.5))
    @settings(max_examples=50, deadline=None)
    def test_p_imp_is_probability_and_monotone(self, i, i_th):
        p = float(p_imp(i, i_th))
        assert 0.0 <= p <= 1.0
        assert float(p_imp(i + 0.01, i_th)) >= p - 1e-6

    def test_budget_percentile_mapping(self):
        samples = np.random.default_rng(0).exponential(size=2000)
        i20 = importance_from_percentile(samples, 0.2)
        i80 = importance_from_percentile(samples, 0.8)
        assert i20 > i80  # larger budget -> lower cutoff
        frac = (samples > i20).mean()
        assert abs(frac - 0.2) < 0.03

    def test_policy_modes(self):
        rng = np.random.default_rng(0)
        pol_all = OffloadPolicy(mode="all")
        pol_none = OffloadPolicy(mode="none")
        assert pol_all.should_offload(rng, 0.99, 0.0)
        assert not pol_none.should_offload(rng, 0.0, 9.9)

    def test_sequence_wise_exit_blocks_offload(self):
        rng = np.random.default_rng(0)
        pol = OffloadPolicy(mode="all")
        assert not pol.should_offload(rng, 0.0, 9.9, seq_pos=95, max_len=100,
                                      seq_exit_frac=0.8)


# ---------------------------------------------------------------------------
# Verifier (draft & verify)
# ---------------------------------------------------------------------------

class TestVerifier:
    def test_greedy_accept_all(self):
        V_ = 16
        draft = np.array([3, 5, 7])
        logits = np.full((4, V_), -10.0)
        for t, tok in enumerate([3, 5, 7, 9]):
            logits[t, tok] = 10.0
        res = V.verify_greedy(draft, logits)
        assert res.n_accepted == 3 and res.bonus == 9
        assert res.tokens == [3, 5, 7, 9]

    def test_greedy_reject_middle(self):
        V_ = 16
        draft = np.array([3, 5, 7])
        logits = np.full((4, V_), -10.0)
        for t, tok in enumerate([3, 6, 7, 9]):
            logits[t, tok] = 10.0
        res = V.verify_greedy(draft, logits)
        assert res.n_accepted == 1
        assert res.corrected == 6
        assert res.tokens == [3, 6]

    def test_greedy_batched_matches_scalar(self):
        rng = np.random.default_rng(0)
        B, gamma, V_ = 8, 4, 32
        draft = rng.integers(0, V_, (B, gamma))
        logits = rng.normal(size=(B, gamma + 1, V_)).astype(np.float32)
        n, c, b = V.verify_greedy_batched(jnp.asarray(draft),
                                          jnp.asarray(logits))
        for i in range(B):
            res = V.verify_greedy(draft[i], logits[i])
            assert int(n[i]) == res.n_accepted
            if res.n_accepted < gamma:
                assert int(c[i]) == res.corrected
            else:
                assert int(b[i]) == res.bonus

    def test_alpha_expected_roundtrip(self):
        for alpha in [0.1, 0.5, 0.9, 0.99]:
            e = V.expected_accepted(alpha, 4)
            a2 = V.alpha_from_expected(e, 4)
            assert abs(a2 - alpha) < 1e-4

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_sample_verify_preserves_target_distribution(self, seed):
        """Leviathan's guarantee: the emitted token at the first position
        is distributed exactly as the target p — regardless of q."""
        rng = np.random.default_rng(seed)
        V_ = 6
        p_logits = rng.normal(size=(2, V_)) * 2
        q_logits = rng.normal(size=(V_,)) * 2
        qp = np.exp(q_logits - q_logits.max())
        qp /= qp.sum()
        idx = np.arange(V_, dtype=np.int32)
        # empirical distribution of the first emitted token
        counts = np.zeros(V_)
        n_trials = 4000
        rr = np.random.default_rng(seed + 1)
        for _ in range(n_trials):
            draft = np.array([rr.choice(V_, p=qp)])
            res = V.verify_sample(draft, p_logits,
                                  [(idx, qp.astype(np.float16))], rr)
            counts[res.tokens[0]] += 1
        emp = counts / n_trials
        target = np.exp(p_logits[0] - p_logits[0].max())
        target /= target.sum()
        # chi-square-ish tolerance
        assert np.abs(emp - target).max() < 0.05


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_greedy_lossless(self):
        rng = np.random.default_rng(0)
        logits = rng.normal(size=32000)
        c = CP.compress(logits, method="greedy")
        assert c.idx[0] == np.argmax(logits)

    def test_topk_support_and_ratio(self):
        rng = np.random.default_rng(1)
        logits = rng.normal(size=32000)
        c = CP.compress(logits, method="top_k", k=8)
        assert len(c.idx) == 8
        ratio = CP.compression_ratio([c], 32000)
        assert ratio > 0.995  # paper: >99.5% reduction

    def test_decompress_normalized(self):
        rng = np.random.default_rng(2)
        c = CP.compress(rng.normal(size=1000), method="top_p", top_p=0.9)
        d = CP.decompress(c, 1000)
        assert abs(d.sum() - 1.0) < 1e-6
        assert (d >= 0).all()

    @given(st.integers(1, 64))
    @settings(max_examples=20, deadline=None)
    def test_topk_keeps_largest(self, k):
        rng = np.random.default_rng(k)
        logits = rng.normal(size=256)
        c = CP.compress(logits, method="top_k", k=k)
        top = np.sort(np.argpartition(logits, -k)[-k:])
        assert set(c.idx.tolist()) == set(top.tolist())


# ---------------------------------------------------------------------------
# Early exit
# ---------------------------------------------------------------------------

class TestEarlyExit:
    def test_exit_only_in_last_quarter(self):
        L, B, V_ = 8, 2, 16
        logits = np.zeros((L, B, V_), np.float32)
        logits[0, :, 3] = 50.0  # extremely confident at layer 0
        ee = EE.EarlyExitConfig(threshold=0.5, eligible_frac=0.25)
        exit_layer, _, _ = EE.pick_exit_layer(jnp.asarray(logits), L, ee)
        assert (np.asarray(exit_layer) >= int(np.ceil(0.75 * L)) - 1).all()

    def test_confident_layer_exits_early(self):
        L, B, V_ = 8, 1, 16
        logits = np.zeros((L, B, V_), np.float32)
        logits[6, :, 3] = 50.0
        logits[7, :, 5] = 50.0
        ee = EE.EarlyExitConfig(threshold=0.5)
        exit_layer, exit_logits, _ = EE.pick_exit_layer(jnp.asarray(logits),
                                                        L, ee)
        assert int(exit_layer[0]) == 6
        assert int(jnp.argmax(exit_logits[0])) == 3

    def test_no_exit_uses_last_layer(self):
        L, B, V_ = 8, 1, 16
        logits = np.zeros((L, B, V_), np.float32)  # uniform: margin 0
        ee = EE.EarlyExitConfig(threshold=0.5)
        exit_layer, _, _ = EE.pick_exit_layer(jnp.asarray(logits), L, ee)
        assert int(exit_layer[0]) == L - 1


# ---------------------------------------------------------------------------
# Parallel inference
# ---------------------------------------------------------------------------

class TestParallelInference:
    @given(st.lists(st.floats(0.01, 0.99), min_size=2, max_size=8),
           st.floats(0.05, 0.95))
    @settings(max_examples=50, deadline=None)
    def test_rejection_distribution_normalized(self, confs, alpha):
        d = PI.rejection_distribution(np.array(confs), alpha)
        assert abs(d.sum() - 1.0) < 1e-9
        assert (d >= 0).all()
        assert len(d) == len(confs) + 1

    def test_high_confidence_predicts_full_accept(self):
        confs = np.full(4, 0.99)
        d = PI.rejection_distribution(confs, alpha=0.95)
        assert d[-1] > 0.9

    def test_low_confidence_predicts_early_reject(self):
        confs = np.full(4, 0.02)
        d = PI.rejection_distribution(confs, alpha=0.3)
        assert d[0] == d.max()

    def test_choose_alternative_excludes_draft(self):
        rng = np.random.default_rng(0)
        idx = np.array([5, 9, 2]); val = np.array([0.5, 0.3, 0.2])
        for _ in range(20):
            alt = PI.choose_alternative(idx, val, draft_token=9, rng=rng)
            assert alt in (5, 2)

    def test_merge_requires_position_and_token(self):
        pi = PI.PIState(r_star=2, alt_token=7)
        adopt, hit = PI.merge(pi, 2, 7, gamma=4)
        assert adopt and hit
        adopt, hit = PI.merge(pi, 2, 8, gamma=4)
        assert (not adopt) and hit
        adopt, hit = PI.merge(pi, 3, 7, gamma=4)
        assert (not adopt) and (not hit)


# ---------------------------------------------------------------------------
# Profiling
# ---------------------------------------------------------------------------

class TestProfiling:
    def test_fit_profile(self, tmp_path):
        rng = np.random.default_rng(0)
        recs = []
        for _ in range(200):
            conf = rng.uniform(0.1, 1.0)
            acc = 4 if conf > 0.75 else rng.integers(0, 4)
            recs.append(ChunkRecord(mean_conf=conf,
                                    mean_imp=rng.exponential(),
                                    n_accepted=int(acc), gamma=4))
        prof = fit_profile(recs)
        assert 0.7 < prof.c_th < 1.0
        assert 0.0 < prof.alpha < 1.0
        i_small = prof.i_th_for_budget(0.1)
        i_big = prof.i_th_for_budget(0.9)
        assert i_small > i_big
        p = tmp_path / "prof.json"
        prof.save(str(p))
        prof2 = SyneraProfile.load(str(p))
        assert abs(prof2.alpha - prof.alpha) < 1e-9
