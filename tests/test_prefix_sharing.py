"""Prefix-sharing paged KV cache: ref-counted copy-on-write blocks.

The headline property (ISSUE 4 acceptance): with ``share_prefix``
enabled, >= 8 concurrent streams sharing a multi-block common prompt
prefix produce greedy token streams byte-identical to the non-sharing
paged path and to dense, while ``peak_used_blocks`` drops by at least
the deduplicated prefix blocks x (streams - 1).  Forced copy-on-write
forks and preemption of a sharing stream both preserve identity.

Layers covered:

* ``BlockAllocator`` units — match/adopt refcounts, release-to-zero
  frees + unregisters, CoW fork bookkeeping, divergence unregistration,
  preempt-while-shared leaving the sibling's blocks live;
* engine-level forced CoW fork (a divergent write into a shared block)
  asserted bit-identical to a non-sharing engine run;
* serving-level acceptance, tight-pool preemption, admission that only
  fits co-resident streams when sharing is on;
* a hypothesis property across block sizes, common-prefix lengths and
  divergence points.

Engines are module-scoped fixtures (jitted steps are expensive to
recompile; released slots are fully reset so reuse is safe).
"""
import numpy as np
import pytest

import jax

from hypothesis import given, settings, strategies as st

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import (BlockAllocator, BlockPoolExhausted,
                                  CloudEngine)
from repro.serving.scheduler import PrefillRequest, VerificationAwareScheduler
from repro.serving import synergy as SY

S_MAX = 256
BS = 8


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=S_MAX, gamma=4, seed=0,
                        policy=OffloadPolicy(mode="all"),
                        use_early_exit=False, use_pi=False)


@pytest.fixture(scope="module")
def eng_dense(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX)


@pytest.fixture(scope="module")
def eng_share8(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=8, s_max=S_MAX,
                       cache_impl="paged", block_size=BS,
                       share_prefix=True)


@pytest.fixture(scope="module")
def eng_noshare8(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=8, s_max=S_MAX,
                       cache_impl="paged", block_size=BS)


def _toks(rng, n):
    return [int(t) for t in rng.integers(1, 60, size=n)]


def _shared_prompts(n_streams, common_len, suffix_lens, seed=11):
    rng = np.random.default_rng(seed)
    common = _toks(rng, common_len)
    return [common + _toks(rng, suffix_lens[i % len(suffix_lens)])
            for i in range(n_streams)]


# ---------------------------------------------------------------------------
# BlockAllocator units
# ---------------------------------------------------------------------------

def test_allocator_match_adopt_refcounts():
    a = BlockAllocator(8, 4, 4, 8, share_prefix=True)
    toks = list(range(1, 13))                   # 12 tokens = 3 full blocks
    assert a.match_prefix(toks) == []           # cold index
    assert a.extend(0, 12)                      # owner allocates 3 blocks
    a.register_prefix(0, toks)
    # matching caps at len-1 tokens: 2 of the 3 full blocks are adoptable
    m = a.match_prefix(toks)
    assert m == [int(a.table[0, j]) for j in range(2)]
    # a longer prompt with the same prefix matches all 3 registered blocks
    assert len(a.match_prefix(toks + [50, 51])) == 3
    # a diverging prompt stops at the divergent block
    assert len(a.match_prefix(toks[:4] + [0] * 8)) == 1
    a.adopt_prefix(1, m)
    assert int(a.n_blocks_of[1]) == 2
    assert all(int(a.ref[b]) == 2 for b in m)
    assert a.used_blocks == 3                   # no physical allocation
    assert a.shared_blocks == 2
    assert a.dedupe_hit_blocks == 2


def test_allocator_release_to_zero_frees_and_unregisters():
    a = BlockAllocator(8, 4, 4, 8, share_prefix=True)
    toks = list(range(1, 13))
    a.extend(0, 12)
    a.register_prefix(0, toks)
    a.adopt_prefix(1, a.match_prefix(toks))
    # releasing the adopter frees nothing physical (all blocks shared)
    freed = a.release(1)
    assert len(freed) == 0 and a.used_blocks == 3
    assert all(int(r) in (0, 1) for r in a.ref)  # back to sole ownership
    # releasing the owner drops every refcount to zero: blocks free AND
    # leave the index (no cross-residency persistence)
    freed = a.release(0)
    assert len(freed) == 3 and a.used_blocks == 0
    assert a.match_prefix(toks) == []


def test_allocator_cow_fork_and_divergence_unregister():
    a = BlockAllocator(8, 4, 4, 8, share_prefix=True)
    toks = list(range(1, 13))
    a.extend(0, 12)
    a.register_prefix(0, toks)
    # the owner's prompt feed realizes the registered content: the
    # fill-pending write neither forks nor unregisters
    assert a.prepare_writes(0, [0, 1, 2]) == []
    assert a.match_prefix(toks + [9]) != []
    m = a.match_prefix(toks)
    a.adopt_prefix(1, m)
    # a divergent write by the adopter into a shared block forks it
    src = m[1]
    pairs = a.prepare_writes(1, [1])
    assert len(pairs) == 1 and pairs[0][0] == src
    dst = pairs[0][1]
    assert int(a.table[1, 1]) == dst != src
    assert int(a.ref[src]) == 1 and int(a.ref[dst]) == 1
    assert a.cow_copies == 1 and a.used_blocks == 4
    # the source block stays registered (its content is intact) ...
    assert a.match_prefix(toks) == m
    # ... and once the adopter is gone, a sole-owner divergent write
    # unpublishes the chain head instead of forking
    a.release(1)
    assert int(a.ref[m[0]]) == 1
    assert a.prepare_writes(0, [0]) == []       # ref == 1: no fork
    assert a.match_prefix(toks) == []


def test_canonical_chain_registration_survives_primary_death():
    """Regression (ROADMAP: canonical-chain registration): a block whose
    chain hash is already indexed (a content duplicate — e.g. the last
    full block of an identical prompt, which sits past match_prefix's
    len-1 cap and is therefore re-allocated) must register as a shadow
    under the *canonical* chain hash.  When the primary dies with its
    owner, the shadow is promoted — without it, a later stream misses a
    share that content-wise still exists in the pool."""
    a = BlockAllocator(12, 4, 4, 8, share_prefix=True)
    toks = list(range(1, 9))                     # [X][Y]: 2 full blocks
    assert a.extend(0, 8)
    a.register_prefix(0, toks)
    a.prepare_writes(0, [0, 1])                  # feed realizes the content
    # an identical prompt adopts [X] only (len-1 cap) and allocates a
    # content duplicate of [Y] behind the shared parent
    m = a.match_prefix(toks)
    assert len(m) == 1
    a.adopt_prefix(1, m)
    assert a.extend(1, 8)
    a.register_prefix(1, toks)
    a.prepare_writes(1, [1])
    dup = int(a.table[1, 1])
    # the original owner dies: its [Y] block frees and leaves the index
    a.release(0)
    # a longer prompt with the same 2-block prefix must match BOTH
    # blocks — the promoted duplicate carries the share
    m2 = a.match_prefix(toks + list(range(20, 26)))
    assert len(m2) == 2 and m2[1] == dup, (m2, dup)
    assert a.shadow_promotions == 1
    # divergent write into the promoted block unpublishes it again
    a.prepare_writes(1, [1])
    assert a.match_prefix(toks + [40]) == [m2[0]]


def test_canonical_chain_shadow_dies_with_its_block():
    """A shadow that frees before its primary must simply leave the
    shadow list (no promotion, no stale index entry)."""
    a = BlockAllocator(12, 4, 4, 8, share_prefix=True)
    toks = list(range(1, 9))
    assert a.extend(0, 8)
    a.register_prefix(0, toks)
    a.prepare_writes(0, [0, 1])
    a.adopt_prefix(1, a.match_prefix(toks))
    assert a.extend(1, 8)
    a.register_prefix(1, toks)
    a.prepare_writes(1, [1])
    a.release(1)                                 # shadow owner dies first
    assert a.shadow_promotions == 0
    # primary intact: the full prefix still matches through slot 0
    assert len(a.match_prefix(toks + [40, 41])) == 2
    a.release(0)
    assert a.match_prefix(toks + [40, 41]) == []
    assert not a._index and not a._shadow and not a._rindex


def test_allocator_cow_fork_requires_free_block():
    a = BlockAllocator(3, 4, 4, 8, share_prefix=True)
    toks = list(range(1, 13))
    a.extend(0, 12)                              # pool fully used
    a.register_prefix(0, toks)
    a.prepare_writes(0, [0, 1, 2])               # consume fill markers
    a.adopt_prefix(1, a.match_prefix(toks))
    with pytest.raises(BlockPoolExhausted):
        a.prepare_writes(1, [0])


# ---------------------------------------------------------------------------
# Engine-level forced CoW fork
# ---------------------------------------------------------------------------

def _drive_cow_script(eng):
    """Prefill two slots with the same prompt (the second adopts under
    sharing), force a divergent write into the shared region for slot 1,
    then decode both slots.  Returns every host-visible output."""
    rng = np.random.default_rng(13)
    P = _toks(rng, 12)                          # 3 blocks at bs=4
    B = eng.max_slots
    out = []

    def prefill(slot, m):
        n = len(P) - m
        t = np.zeros((B, n), np.int32)
        p = np.full((B, n), -1, np.int32)
        t[slot, :n] = P[m:]
        p[slot, :n] = np.arange(m, len(P))
        return eng.prefill(t, p)

    out.append(prefill(0, eng.alloc_prompt(0, P)))
    m1 = eng.alloc_prompt(1, P)
    out.append(prefill(1, m1))
    # divergent write: rewrite slot 1's positions 4..7 (inside the
    # second prompt block, shared when sharing is on) with new tokens
    Q = _toks(rng, 4)
    t = np.zeros((B, 4), np.int32)
    p = np.full((B, 4), -1, np.int32)
    t[1, :] = Q
    p[1, :] = 4 + np.arange(4)
    rows = eng.feed(t, p, need_dists=False)
    out.append(rows.token_id)
    # decode both slots at their next position: slot 0 must be blind to
    # slot 1's rewrite, slot 1 must see it
    td = np.full((B, 1), 7, np.int32)
    pd = np.full((B, 1), -1, np.int32)
    pd[0, 0] = pd[1, 0] = 12
    d = eng.decode(td, pd)
    out += [d.token_id, d.topk_idx, d.topk_val]
    return out, m1


def test_forced_cow_fork_preserves_identity(pair):
    """A divergent write into a shared block forks a private copy: the
    writer sees its new content, the sibling still reads the original,
    and every output matches a non-sharing engine bit-for-bit."""
    _, _, llm_cfg, llm_p = pair
    eng_on = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                         cache_impl="paged", block_size=4,
                         share_prefix=True)
    eng_off = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                          cache_impl="paged", block_size=4)
    got_on, m_on = _drive_cow_script(eng_on)
    got_off, m_off = _drive_cow_script(eng_off)
    # sharing actually engaged: 2 whole blocks + 3 tail rows (len-1 cap)
    assert m_on == 11 and m_off == 0
    a = eng_on.allocator
    assert a.cow_copies == 1                    # exactly one fork
    assert a.dedupe_hit_blocks == 2
    for i, (x, y) in enumerate(zip(got_on, got_off)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), f"output {i}"
    # slot 0 still shares nothing it wrote; fork dropped the share
    assert a.shared_blocks == 1                 # only block 0 still shared
    eng_on.reset_slot(0)
    eng_on.reset_slot(1)
    eng_off.reset_slot(0)
    eng_off.reset_slot(1)
    assert a.used_blocks == 0


# ---------------------------------------------------------------------------
# Serving-level acceptance
# ---------------------------------------------------------------------------

def test_shared_prefix_acceptance(dev, eng_dense, eng_share8, eng_noshare8):
    """ISSUE 4 acceptance: 8 concurrent streams sharing a 3-block common
    prefix — byte-identical to non-sharing paged and to dense, with peak
    pool usage down by >= shared blocks x (streams - 1)."""
    n = 8
    prompts = _shared_prompts(n, common_len=3 * BS, suffix_lens=[BS],
                              seed=11)
    r_ref = SY.run_synera(dev, eng_dense, prompts, 10, concurrency=1)
    r_off = SY.run_synera(dev, eng_noshare8, prompts, 10, concurrency=n)
    r_on = SY.run_synera(dev, eng_share8, prompts, 10, concurrency=n)
    assert r_off.outputs == r_ref.outputs
    assert r_on.outputs == r_ref.outputs
    st_off = r_off.extras["scheduler"]
    st_on = r_on.extras["scheduler"]
    assert st_on["share_prefix"] and not st_off["share_prefix"]
    # 3 common full blocks dedupe across the 7 adopting streams
    assert st_on["dedupe_hit_blocks"] >= 3 * (n - 1)
    drop = st_off["peak_used_blocks"] - st_on["peak_used_blocks"]
    assert drop >= 3 * (n - 1), (st_off, st_on)
    # pool fully drained, index emptied with the last reference
    assert eng_share8.allocator.used_blocks == 0
    assert eng_share8.allocator.shared_blocks == 0
    assert len(eng_share8.allocator._index) == 0


def test_preempt_sharing_stream_preserves_identity(dev, eng_dense, pair):
    """A pool too small for all sharing streams forces preemption of a
    stream that holds shared blocks: its refs are released (never freeing
    a block out from under a sibling), it refeeds from scratch, and the
    final token streams stay byte-identical to dense."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                      cache_impl="paged", block_size=4, pool_blocks=11,
                      share_prefix=True)
    prompts = _shared_prompts(4, common_len=8, suffix_lens=[4], seed=29)
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r_pg = SY.run_synera(dev, eng, prompts, 12, concurrency=4)
    assert r_pg.outputs == r_ref.outputs
    st_ = r_pg.extras["scheduler"]
    assert st_["preemptions"] >= 1
    assert st_["dedupe_hit_blocks"] >= 1
    assert eng.allocator.used_blocks == 0
    assert eng.allocator.free_blocks == eng.allocator.n_blocks


def test_admission_fits_only_with_sharing(pair):
    """One prefill iteration admits all 4 streams only when the common
    prefix dedupes: 4 x 3-block prompts on a 7-block pool (cold cost 12,
    shared cost 3 + 3 x 1 = 6)."""
    _, _, llm_cfg, llm_p = pair
    rng = np.random.default_rng(31)
    common = _toks(rng, 8)                       # 2 blocks at bs=4
    prompts = [common + _toks(rng, 4) for _ in range(4)]

    def admitted(share):
        eng = CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=64,
                          cache_impl="paged", block_size=4, pool_blocks=7,
                          share_prefix=share)
        sched = VerificationAwareScheduler(eng, chunk=8)
        for rid, p in enumerate(prompts):
            sched.submit_prefill(PrefillRequest(rid + 1, np.asarray(p)))
        evs = sched.run_iteration()
        n_adm = len(evs)
        stats = dict(eng.pool_stats)
        for s in range(eng.max_slots):
            if eng.allocator.n_blocks_of[s] > 0:
                sched.release_slot(s)
        assert eng.allocator.used_blocks == 0
        return n_adm, stats

    n_on, st_on = admitted(True)
    n_off, st_off = admitted(False)
    assert n_on == 4, st_on                      # all co-resident
    assert n_off == 2, st_off                    # pool-bound without dedupe
    assert st_on["used_blocks"] == 6 and st_on["shared_blocks"] == 2
    assert st_on["dedupe_hit_blocks"] == 6       # 2 blocks x 3 adopters


def test_same_batch_adoption_survives_feed_split(dev, pair):
    """Regression: when the bucket ladder splits a prompt batch into
    sequential sub-chunks, a same-iteration adopter's suffix rows must
    not attend before its filler has scattered the shared prefix.  The
    scheduler aligns prefill columns with absolute positions (shared
    prefix = leading padding), so sub-chunk k writes position range k
    for every slot before any later sub-chunk reads it.  With a tiny
    ladder and an unaligned feed this diverged streams silently."""
    _, _, llm_cfg, llm_p = pair

    def mk(share):
        return CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=64,
                           cache_impl="paged", block_size=4,
                           share_prefix=share, feed_buckets=(8,))

    prompts = _shared_prompts(4, common_len=16, suffix_lens=[5, 7],
                              seed=61)
    r_off = SY.run_synera(dev, mk(False), prompts, 8, concurrency=4)
    r_on = SY.run_synera(dev, mk(True), prompts, 8, concurrency=4)
    assert r_on.outputs == r_off.outputs
    assert r_on.extras["scheduler"]["dedupe_hit_blocks"] > 0


# ---------------------------------------------------------------------------
# Property: identity across block sizes, prefix lengths, divergence points
# ---------------------------------------------------------------------------

@given(st.integers(4, 24),        # common prefix length (any divergence pt)
       st.integers(2, 4),         # number of streams
       st.integers(1, 11))        # suffix length seed
@settings(max_examples=5, deadline=None)
def test_shared_prefix_property(dev, eng_share8, eng_noshare8,
                                common_len, n_streams, suffix_seed):
    """Streams with a common prefix produce byte-identical greedy
    outputs with and without sharing, wherever the divergence point
    falls relative to block boundaries."""
    rng = np.random.default_rng(common_len * 31 + n_streams * 7
                                + suffix_seed)
    suffix_lens = [int(rng.integers(1, 12)) for _ in range(n_streams)]
    prompts = _shared_prompts(n_streams, common_len, suffix_lens,
                              seed=suffix_seed + 3)
    r_off = SY.run_synera(dev, eng_noshare8, prompts, 8,
                          concurrency=n_streams)
    r_on = SY.run_synera(dev, eng_share8, prompts, 8,
                         concurrency=n_streams)
    assert r_on.outputs == r_off.outputs
    assert eng_share8.allocator.used_blocks == 0
