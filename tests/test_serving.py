"""Serving-system integration tests: engine, verification-aware
scheduler (Algorithm 1), device runtime, and the end-to-end equivalence
invariants of token-level synergy."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.scheduler import (PrefillRequest, VerifyRequest,
                                     VerificationAwareScheduler)
from repro.serving import synergy as SY


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


PROMPTS = [[1, 2, 3, 4, 5, 6, 7, 8], [9, 8, 7, 6, 5, 4, 3]]


def test_scheduler_prefill_priority(pair):
    """Algorithm 1: while prefills are queued, verifications wait."""
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=2, s_max=128)
    sched = VerificationAwareScheduler(eng)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 9)))
    evs = sched.run_iteration()
    assert [e.kind for e in evs] == ["prefill_done"]
    slot = evs[0].slot
    sched.submit_prefill(PrefillRequest(2, np.arange(2, 9)))
    sched.submit_verify(VerifyRequest(3, slot, uncached=np.array([], np.int64),
                                      draft=np.array([1, 2, 3, 4]),
                                      q_sparse=None))
    evs = sched.run_iteration()
    assert [e.kind for e in evs] == ["prefill_done"]  # prefill first
    evs = sched.run_iteration()
    assert [e.kind for e in evs] == ["verify_done"]


def test_scheduler_chunked_partial_prefill(pair):
    """A verification request longer than the Sarathi chunk is fed over
    multiple iterations and completes with the right cloud frontier."""
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=1, s_max=256)
    sched = VerificationAwareScheduler(eng, chunk=32)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 9)))
    sched.run_iteration()
    long_uncached = np.random.default_rng(0).integers(1, 60, size=70)
    sched.submit_verify(VerifyRequest(2, 0, uncached=long_uncached,
                                      draft=np.array([5, 6, 7, 8]),
                                      q_sparse=None))
    iters = 0
    done = []
    while sched.has_work() and iters < 10:
        done += sched.run_iteration()
        iters += 1
    assert any(e.kind == "verify_done" for e in done)
    # 74 tokens at chunk 32 -> 3 feed iterations
    assert iters >= 3
    res = done[-1].result
    assert sched.cloud_len[0] == 8 + 70 + res.n_accepted


def test_engine_slot_isolation(pair):
    """Two slots decode independently: interleaved single-slot decode
    equals batched decode."""
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=2, s_max=64)
    toks = np.zeros((2, 8), np.int32)
    toks[0] = np.arange(1, 9); toks[1] = np.arange(9, 1, -1)
    pos = np.broadcast_to(np.arange(8), (2, 8)).astype(np.int32).copy()
    logits = eng.feed_logits(toks, pos)
    # reference: per-sequence full forward
    for b in range(2):
        full, _, _, _ = M.forward(slm_cfg, slm_p, jnp.asarray(toks[b:b+1]),
                                  M.default_positions(1, 8))
        np.testing.assert_allclose(logits[b], np.asarray(full[0]),
                                   atol=2e-4, rtol=2e-3)


def test_engine_reset_slot(pair):
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=2, s_max=64)
    toks = np.tile(np.arange(1, 9, dtype=np.int32), (2, 1))
    pos = np.broadcast_to(np.arange(8), (2, 8)).astype(np.int32).copy()
    eng.feed(toks, pos)
    eng.reset_slot(0)
    # slot 1 must be unaffected: decode continues correctly
    t = np.array([[3], [3]], np.int32)
    p = np.array([[8], [8]], np.int32)
    logits = eng.decode_logits(t, p)
    ref_toks = np.concatenate([toks[1], [3]])
    full, _, _, _ = M.forward(slm_cfg, slm_p, jnp.asarray(ref_toks[None]),
                              M.default_positions(1, 9))
    np.testing.assert_allclose(logits[1], np.asarray(full[0, -1]),
                               atol=2e-4, rtol=2e-3)
    # fused decode at the same position (cache_write is idempotent per
    # position, so re-decoding token 3 @ 8 reproduces the same row)
    rows = eng.decode(t, p)
    assert int(rows.token_id[1]) == int(np.argmax(full[0, -1]))
    assert int(rows.topk_idx[1, 0]) == int(rows.token_id[1])


def test_synera_offload_all_equals_cloud_greedy(pair):
    """The central speculative-decoding invariant: offloading every chunk
    with greedy verification reproduces the cloud LLM's greedy stream."""
    slm_cfg, slm_p, llm_cfg, llm_p = pair
    dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0)
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256)
    r_cloud = SY.run_cloud_centric(eng, PROMPTS, 20)
    r_syn = SY.run_synera(dev, eng, PROMPTS, 20, profile_mode=True)
    assert r_syn.outputs == r_cloud.outputs


def test_synera_pi_exactness(pair):
    """Stall-free parallel inference must never change the token stream
    (only mask latency)."""
    slm_cfg, slm_p, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256)
    r_cloud = SY.run_cloud_centric(eng, PROMPTS, 20)
    dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                        policy=OffloadPolicy(mode="all"),
                        use_early_exit=False, use_pi=True)
    r = SY.run_synera(dev, eng, PROMPTS, 20)
    assert r.outputs == r_cloud.outputs


def test_synera_pi_adoption_with_identical_models(pair):
    """SLM == LLM: every draft accepted; PI full-accept predictions adopt
    and the stream still exactly matches."""
    slm_cfg, slm_p, _, _ = pair
    eng = CloudEngine(slm_cfg, slm_p, max_slots=2, s_max=256)
    r_cloud = SY.run_cloud_centric(eng, PROMPTS, 20)
    dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                        policy=OffloadPolicy(mode="all"),
                        use_early_exit=False, use_pi=True, alpha=0.97)
    r = SY.run_synera(dev, eng, PROMPTS, 20)
    assert r.outputs == r_cloud.outputs
    m = r.metrics[0]
    assert m.acceptance_rate > 0.99


def test_edge_centric_runs_locally(pair):
    slm_cfg, slm_p, _, _ = pair
    dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0)
    r = SY.run_edge_centric(dev, PROMPTS, 16)
    for m in r.metrics:
        assert m.n_cloud_tokens == 0
        assert len(m.tokens) == 16
    assert r.cloud_fed_frac == 0.0


def test_baselines_run(pair):
    slm_cfg, slm_p, llm_cfg, llm_p = pair
    dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0)
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256)
    rh = SY.run_hybrid(dev, eng, PROMPTS, 12)
    re = SY.run_edgefm(dev, eng, PROMPTS, 12)
    assert all(len(o) == 12 for o in rh.outputs)
    assert all(len(o) == 12 for o in re.outputs)
    # EdgeFM sends ~half the prompts (median threshold) fully to cloud
    fracs = [m.cloud_token_frac for m in re.metrics]
    assert any(f == 0 for f in fracs) and any(f > 0.9 for f in fracs)


def test_device_profile_mode_records(pair):
    slm_cfg, slm_p, llm_cfg, llm_p = pair
    dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0)
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256)
    r = SY.run_synera(dev, eng, PROMPTS[:1], 16, profile_mode=True)
    recs = r.metrics[0].chunk_records
    assert len(recs) >= 3
    assert all(0 <= c.n_accepted <= c.gamma for c in recs)
