"""Paged KV cache: allocator mechanics, dense/paged byte-identity at the
engine and serving levels, memory-bound admission, youngest-stream
preemption, FIFO slot reuse, and slot oversubscription.

The headline property (ISSUE 3 acceptance): greedy token streams under
``cache_impl="paged"`` are byte-identical to ``"dense"`` across random
prompt lengths, arrival patterns and block sizes — including when the
pool runs dry and streams are preempted.

Engines and the device runtime are module-scoped fixtures (jitted steps
are expensive to recompile, released slots are fully reset — reuse is
safe; see test_server.py).
"""
import numpy as np
import pytest

import jax

from hypothesis import given, settings, strategies as st

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import BlockAllocator, CloudEngine
from repro.serving.scheduler import PrefillRequest, VerificationAwareScheduler
from repro.serving.server import SyneraServer
from repro.serving import synergy as SY

S_MAX = 256


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=S_MAX, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


@pytest.fixture(scope="module")
def eng_dense(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX)


@pytest.fixture(scope="module")
def paged_engines(pair):
    """Paged engines across block sizes, including a deliberately tight
    pool (forces preemption under concurrent load)."""
    _, _, llm_cfg, llm_p = pair
    return [
        CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                    cache_impl="paged", block_size=4),
        CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                    cache_impl="paged", block_size=16),
        CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                    cache_impl="paged", block_size=4, pool_blocks=11),
    ]


def _prompts(lens, seed=5):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 60, size=max(L, 2))]
            for L in lens]


# ---------------------------------------------------------------------------
# BlockAllocator unit behavior
# ---------------------------------------------------------------------------

def test_allocator_mechanics():
    a = BlockAllocator(n_blocks=6, block_size=4, max_slots=3,
                       max_blocks_per_slot=4)
    assert a.blocks_for(1) == 1 and a.blocks_for(4) == 1
    assert a.blocks_for(5) == 2
    assert a.blocks_for(10_000) == 4          # capped at max_bps (window)
    assert a.extend(0, 7)                     # 2 blocks
    assert a.extend(1, 9)                     # 3 blocks
    assert a.free_blocks == 1 and a.used_blocks == 5
    # all-or-nothing: 2 more blocks for slot 0 cannot be met, no change
    assert not a.extend(0, 16)
    assert a.free_blocks == 1 and a.n_blocks_of[0] == 2
    # growth within the allocation is free
    assert a.extend(0, 8) and a.n_blocks_of[0] == 2
    freed = a.release(1)
    assert len(freed) == 3 and a.free_blocks == 4
    assert (a.table[1] == -1).all()
    assert a.peak_used == 5
    # FIFO recycling: freed blocks come back after the original tail
    assert a.extend(2, 16)
    order = list(a.table[2][a.table[2] >= 0])
    assert order[-len(freed):] == list(freed)


def test_paged_init_cache_guards():
    slm_cfg, _ = tiny_pair(vocab=64)
    with pytest.raises(ValueError):
        M.init_cache(slm_cfg, 2, 100, cache_impl="paged", block_size=16)
    bad = slm_cfg.replace(family="ssm", ssm_state=16)
    with pytest.raises(ValueError):
        M.init_cache(bad, 2, 256, cache_impl="paged")


# ---------------------------------------------------------------------------
# Engine-level byte-identity (prefill / feed / decode / reset_slot)
# ---------------------------------------------------------------------------

def _drive_engine(eng):
    rng = np.random.default_rng(3)
    B, R = eng.max_slots, eng.verify_rows_max
    out = []
    tokens = np.zeros((B, 12), np.int32)
    positions = np.full((B, 12), -1, np.int32)
    tokens[0, :8] = rng.integers(1, 60, 8)
    positions[0, :8] = np.arange(8)
    tokens[1, :12] = rng.integers(1, 60, 12)
    positions[1, :12] = np.arange(12)
    out.append(eng.prefill(tokens, positions))
    t2 = np.zeros((B, 6), np.int32)
    p2 = np.full((B, 6), -1, np.int32)
    tg = np.full((B, 6), -1, np.int32)
    sel = np.full((B, R), -1, np.int32)
    t2[0] = rng.integers(1, 60, 6)
    p2[0] = 8 + np.arange(6)
    t2[1] = rng.integers(1, 60, 6)
    p2[1] = 12 + np.arange(6)
    tg[:, :5] = t2[:, 1:]
    sel[:, :3] = [3, 4, 5]
    rows = eng.feed(t2, p2, tg, sel, need_dists=True)
    out += [rows.token_id, rows.p_draft, rows.topk_idx, rows.topk_val]
    td = np.zeros((B, 1), np.int32)
    pd = np.full((B, 1), -1, np.int32)
    td[0, 0], pd[0, 0] = 5, 14
    d = eng.decode(td, pd)
    out += [d.token_id, d.topk_idx, d.topk_val]
    eng.reset_slot(1)
    t3 = np.zeros((B, 4), np.int32)
    p3 = np.full((B, 4), -1, np.int32)
    t3[1] = rng.integers(1, 60, 4)
    p3[1] = np.arange(4)
    out.append(eng.prefill(t3, p3))
    eng.reset_slot(0)
    eng.reset_slot(1)
    return out


def test_engine_paged_dense_identity(pair):
    """Every engine output (prefill rows, fused verify rows, decode rows,
    post-reset re-prefill) is byte-identical between cache layouts."""
    _, _, llm_cfg, llm_p = pair
    eng_d = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64)
    eng_p = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                        cache_impl="paged", block_size=8, pool_blocks=12)
    for i, (a, b) in enumerate(zip(_drive_engine(eng_d),
                                   _drive_engine(eng_p))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), f"output {i}"
    assert eng_p.allocator.used_blocks == 0      # resets returned the pool
    assert eng_p.pool_stats["peak_used_blocks"] > 0


# ---------------------------------------------------------------------------
# Serving-level equivalence (the acceptance property)
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(4, 20), min_size=1, max_size=3),
       st.integers(0, 2),        # which paged engine (block size / pool)
       st.integers(0, 1))        # arrival pattern: together | staggered
@settings(max_examples=5, deadline=None)
def test_paged_matches_dense_streams(dev, eng_dense, paged_engines,
                                     lens, eng_i, arr_i):
    """Greedy token streams under cache_impl='paged' are byte-identical
    to 'dense' across prompt lengths, arrival patterns and block sizes
    (tight-pool engine 2 adds forced preemption to the mix)."""
    prompts = _prompts(lens, seed=sum(lens) + 7 * len(lens))
    arrivals = None if arr_i == 0 else [i * 350.0 for i
                                        in range(len(prompts))]
    r_ref = SY.run_synera(dev, eng_dense, prompts, 10, concurrency=1)
    r_pg = SY.run_synera(dev, paged_engines[eng_i], prompts, 10,
                         concurrency=len(prompts), arrivals=arrivals)
    assert r_pg.outputs == r_ref.outputs
    st_ = r_pg.extras["scheduler"]
    assert st_["cache_impl"] == "paged"
    assert st_["used_blocks"] == 0               # fully drained at the end


@pytest.fixture(scope="module")
def pallas_engines(pair):
    """attn_impl='pallas' engines: the dense-cache reference plus paged
    variants across block size and fused-DMA / split-KV settings."""
    _, _, llm_cfg, llm_p = pair
    cfg = llm_cfg.replace(attn_impl="pallas")
    dense = CloudEngine(cfg, llm_p, max_slots=2, s_max=S_MAX)
    paged = [
        # unfused single-pass (block_kv == block_size -> fuse=1)
        CloudEngine(cfg, llm_p, max_slots=2, s_max=S_MAX,
                    cache_impl="paged", block_size=16,
                    paged_block_kv=16, kv_splits=1),
        # fused multi-block DMA (fuse=8)
        CloudEngine(cfg, llm_p, max_slots=2, s_max=S_MAX,
                    cache_impl="paged", block_size=16,
                    paged_block_kv=128, kv_splits=1),
        # fused + flash-decode split-KV
        CloudEngine(cfg, llm_p, max_slots=2, s_max=S_MAX,
                    cache_impl="paged", block_size=16,
                    paged_block_kv=64, kv_splits=4),
    ]
    return dense, paged


@given(st.lists(st.integers(4, 20), min_size=1, max_size=2),
       st.integers(0, 2))        # which paged pallas engine
@settings(max_examples=3, deadline=None)
def test_paged_pallas_streams_match_dense(dev, pallas_engines, lens,
                                          eng_i):
    """The paged Pallas kernels (fused DMA, split-KV) are serving-level
    exact: greedy token streams are byte-identical to the dense-cache
    pallas engine across prompt lengths and fuse/split settings."""
    dense, paged = pallas_engines
    prompts = _prompts(lens, seed=sum(lens) + 11 * len(lens))
    r_ref = SY.run_synera(dev, dense, prompts, 8, concurrency=1)
    r_pg = SY.run_synera(dev, paged[eng_i], prompts, 8,
                         concurrency=len(prompts))
    assert r_pg.outputs == r_ref.outputs
    assert r_pg.extras["scheduler"]["cache_impl"] == "paged"


def test_forced_preemption_keeps_streams_identical(dev, eng_dense,
                                                   paged_engines):
    """A pool too small for two full streams forces youngest-stream
    preemption; evicted streams refeed from scratch and the final token
    streams stay byte-identical to the dense run."""
    eng_tight = paged_engines[2]                 # 11 blocks of 4 tokens
    prompts = _prompts([8, 8, 8, 8], seed=29)
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r_pg = SY.run_synera(dev, eng_tight, prompts, 12, concurrency=4)
    assert r_pg.outputs == r_ref.outputs
    st_ = r_pg.extras["scheduler"]
    assert st_["preemptions"] >= 1
    assert st_["preempted_refed_tokens"] > 0
    assert st_["used_blocks"] == 0
    assert eng_tight.allocator.free_blocks == eng_tight.allocator.n_blocks


def test_paged_serves_4x_slots_oversubscribed(dev, eng_dense, pair):
    """Acceptance: a paged engine serves >= 4x max_slots concurrent
    greedy streams (waiting-queue admission) with token streams
    byte-identical to the dense path, while its peak memory stays well
    under the dense reservation."""
    _, _, llm_cfg, llm_p = pair
    eng_p = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                        cache_impl="paged", block_size=8)
    prompts = _prompts([8] * 8, seed=41)          # 8 streams on 2 slots
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r_pg = SY.run_synera(dev, eng_p, prompts, 12, concurrency=8)
    assert r_pg.outputs == r_ref.outputs
    st_ = r_pg.extras["scheduler"]
    # memory bound: peak live KV is a fraction of the dense reservation
    assert st_["kv_bytes_peak"] * 2 < st_["kv_cache_bytes"]
    assert st_["max_verify_occupancy"] >= 2      # batching still happens


def test_block_admission_gates_prefill(pair):
    """Prefill admission on a paged engine checks free *blocks*: with
    free slots but a dry pool the prompt stays queued, and is admitted
    once another stream releases its blocks."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=64,
                      cache_impl="paged", block_size=4, pool_blocks=5)
    sched = VerificationAwareScheduler(eng, chunk=8)
    for rid in (1, 2, 3):                        # 8-token prompts: 2 blocks
        sched.submit_prefill(PrefillRequest(rid, np.arange(1, 9)))
    evs = sched.run_iteration()
    assert sorted(e.req_id for e in evs) == [1, 2]   # 4 of 5 blocks used
    assert len(sched.free_slots) == 2            # slots were NOT the limit
    assert len(sched.prefill_q) == 1
    sched.release_slot(evs[0].slot)
    evs = sched.run_iteration()
    assert [e.req_id for e in evs] == [3]
    for s in range(eng.max_slots):
        if eng.allocator.n_blocks_of[s] > 0:
            sched.release_slot(s)
    assert eng.allocator.used_blocks == 0


def test_prefill_rejects_prompt_larger_than_pool(pair):
    """A prompt that could never fit even a drained pool fails loudly
    with the sizing contract instead of deferring forever."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                      cache_impl="paged", block_size=4, pool_blocks=2)
    sched = VerificationAwareScheduler(eng, chunk=8)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 33)))  # 8 > 2 blocks
    with pytest.raises(RuntimeError, match="pool too small"):
        sched.run_iteration()


def test_prefill_block_admission_is_fcfs(pair):
    """A prompt deferred for lack of blocks must not be bypassed by
    later-arriving smaller prompts — otherwise a steady small-prompt
    stream starves the large one indefinitely."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=64,
                      cache_impl="paged", block_size=4, pool_blocks=6)
    sched = VerificationAwareScheduler(eng, chunk=8)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 9)))     # 2 blocks
    assert [e.req_id for e in sched.run_iteration()] == [1]
    sched.submit_prefill(PrefillRequest(2, np.arange(1, 25)))    # 6 blocks
    sched.submit_prefill(PrefillRequest(3, np.arange(1, 5)))     # 1 block
    assert sched.run_iteration() == []          # 3 must wait behind 2
    assert len(sched.prefill_q) == 2
    sched.release_slot(0)                       # now 6 blocks free
    evs = sched.run_iteration()
    assert [e.req_id for e in evs] == [2]       # FCFS; 3 still queued
    sched.release_slot(evs[0].slot)
    assert [e.req_id for e in sched.run_iteration()] == [3]
    for s in range(eng.max_slots):
        if eng.allocator.n_blocks_of[s] > 0:
            sched.release_slot(s)


# ---------------------------------------------------------------------------
# FIFO slot reuse (regression: LIFO free-list made one slot absorb all
# churn)
# ---------------------------------------------------------------------------

def test_slot_reuse_round_robins(dev, pair):
    """Sequential sessions on a 2-slot engine must round-robin over both
    physical rows ([0, 1, 0, 1]), not hammer one slot."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX)
    server = SyneraServer(dev, eng)
    server.serve(_prompts([8, 8, 8, 8], seed=47), 8, concurrency=1)
    used = [slot for s in server.sessions for slot in s.slots_used]
    assert used == [0, 1, 0, 1]


def test_slot_reuse_round_robins_staggered(dev, pair):
    """Same property under staggered arrivals with overlap: releases go
    to the back of the FIFO, so reuse alternates instead of popping the
    most recently freed row every time."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX)
    server = SyneraServer(dev, eng)
    server.serve(_prompts([8, 8, 8, 8, 8, 8], seed=53), 8,
                 concurrency=None,
                 arrivals=[0.0, 2000.0, 4000.0, 6000.0, 8000.0, 10000.0])
    used = [slot for s in server.sessions for slot in s.slots_used]
    assert len(used) == 6
    # strictly sequential arrivals + FIFO recycling => alternating rows
    assert used == [0, 1, 0, 1, 0, 1]
