"""Test bootstrap.

The CI/CPU container does not ship ``hypothesis``; rather than skipping
the property-test files wholesale (a collection error), install a
minimal deterministic stand-in that draws a fixed number of
pseudo-random examples per test.  It implements exactly the surface the
suite uses: ``given``, ``settings(max_examples=, deadline=)`` and the
``integers`` / ``floats`` / ``lists`` / ``tuples`` strategies.  When the
real hypothesis is installed it is used untouched.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

try:  # pragma: no cover - prefer the real package when present
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem._draw(rng)
            for _ in range(rng.randint(min_size, max_size))])

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elems))

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def _given(*strats, **kwstrats):
        def deco(fn):
            # positional strategies bind to the RIGHTMOST parameters
            # (hypothesis semantics), so drawn values must be passed by
            # name — tests mixing pytest fixtures with @given rely on it
            names = list(inspect.signature(fn).parameters)
            drawn = names[len(names) - len(strats):] if strats else []

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 20))
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    vals = dict(zip(drawn, (s._draw(rng) for s in strats)))
                    kvals = {k: s._draw(rng) for k, s in kwstrats.items()}
                    fn(*args, **kwargs, **vals, **kvals)
            # hide the drawn parameters from pytest so it does not try
            # to resolve them as fixtures (real hypothesis does the same)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if strats:
                params = params[:len(params) - len(strats)]
            params = [p for p in params if p.name not in kwstrats]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.lists = _lists
    _st.tuples = _tuples
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
