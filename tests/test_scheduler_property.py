"""Hypothesis property tests for the verification-aware scheduler
(Algorithm 1) against a stub engine — no model compute, so arbitrary
workload interleavings can be explored quickly."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serving.engine import VerifyRows
from repro.serving.scheduler import (PrefillRequest, VerifyRequest,
                                     VerificationAwareScheduler)


class StubEngine:
    """Deterministic no-compute engine speaking the fused interface:
    the row at position p has argmax (p * 7) % vocab with all its mass
    there."""

    def __init__(self, max_slots=4, vocab=32):
        self.max_slots = max_slots
        self.vocab = vocab
        self.verify_top_k = min(8, vocab)
        self.fed = []          # (slot, pos) log

    def _tok(self, pos: int) -> int:
        return (pos * 7) % self.vocab

    def feed(self, tokens, positions, targets=None, sel_idx=None,
             need_dists=True):
        B, C = tokens.shape
        for s in range(B):
            for j in range(C):
                if positions[s, j] >= 0:
                    self.fed.append((s, int(positions[s, j])))
        R = sel_idx.shape[1] if sel_idx is not None else 1
        tok = np.zeros((B, R), np.int32)
        p_t = np.zeros((B, R), np.float32)
        tk_i = np.zeros((B, R, 1), np.int32)
        tk_v = np.zeros((B, R, 1), np.float32)
        if sel_idx is not None:
            for s in range(B):
                for r in range(R):
                    i = int(sel_idx[s, r])
                    if i < 0 or positions[s, i] < 0:
                        continue
                    t = self._tok(int(positions[s, i]))
                    tok[s, r] = t
                    tk_i[s, r, 0] = t
                    tk_v[s, r, 0] = 1.0
                    if targets is not None and targets[s, i] == t:
                        p_t[s, r] = 1.0
        return VerifyRows(tok, p_t, tk_i, tk_v)

    def prefill(self, tokens, positions):
        B = tokens.shape[0]
        out = np.zeros((B, self.vocab), np.float32)
        for s in range(B):
            valid = positions[s][positions[s] >= 0]
            if len(valid):
                out[s, self._tok(int(valid.max()))] = 1.0
        return out

    def reset_slot(self, slot):
        pass


workload = st.lists(
    st.tuples(
        st.integers(1, 40),    # prompt len
        st.lists(st.tuples(st.integers(0, 50),   # uncached len
                           st.integers(1, 4)),   # gamma
                 min_size=1, max_size=4),
    ),
    min_size=1, max_size=4)


@given(workload)
@settings(max_examples=30, deadline=None)
def test_scheduler_completes_all_requests(wl):
    eng = StubEngine(max_slots=4)
    sched = VerificationAwareScheduler(eng, chunk=8)
    rid = 0
    expected = set()
    streams = []
    for prompt_len, verifies in wl:
        rid += 1
        sched.submit_prefill(PrefillRequest(rid, np.arange(1, prompt_len + 1)))
        expected.add(("prefill_done", rid))
        streams.append((rid, prompt_len, verifies))

    done = {}
    for _ in range(500):
        for ev in sched.run_iteration():
            done[(ev.kind, ev.req_id)] = ev
        if expected <= set(done):
            break
    assert expected <= set(done)

    # now submit the verification stream per slot, sequentially
    for rid0, prompt_len, verifies in streams:
        slot = done[("prefill_done", rid0)].slot
        frontier = prompt_len
        for unc_len, gamma in verifies:
            if gamma + 1 > sched.chunk:
                continue
            rid += 1
            unc = np.arange(unc_len) % 31 + 1
            draft = np.arange(gamma) + 1
            sched.submit_verify(VerifyRequest(rid, slot, uncached=unc,
                                              draft=draft, q_sparse=None))
            got = None
            for _ in range(200):
                for ev in sched.run_iteration():
                    if ev.req_id == rid:
                        got = ev
                if got:
                    break
            assert got is not None and got.kind == "verify_done"
            res = got.result
            # frontier advances by uncached + accepted tokens
            assert sched.cloud_len[slot] == frontier + unc_len + res.n_accepted
            frontier = int(sched.cloud_len[slot])
            assert 0 <= res.n_accepted <= gamma


@given(st.integers(1, 100), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_chunking_feeds_contiguous_positions(unc_len, gamma):
    eng = StubEngine(max_slots=1)
    sched = VerificationAwareScheduler(eng, chunk=8)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 6)))
    while not any(e.kind == "prefill_done" for e in sched.run_iteration()):
        pass
    if gamma + 1 > sched.chunk:
        return
    eng.fed.clear()
    sched.submit_verify(VerifyRequest(2, 0,
                                      uncached=np.ones(unc_len, np.int64),
                                      draft=np.ones(gamma, np.int64),
                                      q_sparse=None))
    for _ in range(100):
        if any(e.kind == "verify_done" for e in sched.run_iteration()):
            break
    positions = [p for s, p in eng.fed if s == 0]
    # every position 5..5+unc_len+gamma-1 fed exactly once, in order
    assert positions == list(range(5, 5 + unc_len + gamma))
