"""Multi-tenant serving tests: SyneraServer event loop, cross-stream
batching in the verification-aware scheduler, token-identity with the
sequential path, slot reuse across staggered arrivals, and the
head-of-line deadlock regression.

Engines and device runtimes are module-scoped fixtures: instantiating
them recompiles their jitted steps, and released slots are fully reset,
so reuse across tests (and across the sequential/concurrent runs inside
one test) is both safe and much faster.
"""
import numpy as np
import pytest

import jax

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.scheduler import (PrefillRequest, VerifyRequest,
                                     VerificationAwareScheduler)
from repro.serving.server import SyneraServer
from repro.serving import synergy as SY


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev_nopi(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


@pytest.fixture(scope="module")
def dev_pi(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=True)


@pytest.fixture(scope="module")
def eng2(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256)


@pytest.fixture(scope="module")
def eng8(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=8, s_max=256)


def _prompts(n, length=8):
    rng = np.random.default_rng(5)
    return [[int(t) for t in rng.integers(1, 60, size=length)]
            for _ in range(n)]


def test_multistream_batches_and_matches_sequential(dev_pi, eng8):
    """With 3 concurrent sessions, at least one cloud iteration packs
    verify chunks for >= 2 slots, and greedy outputs are token-identical
    to the sequential concurrency=1 run (PI stays exactness-preserving
    under interleaving)."""
    prompts = _prompts(3)
    r_seq = SY.run_synera(dev_pi, eng8, prompts, 16, concurrency=1)
    r_con = SY.run_synera(dev_pi, eng8, prompts, 16, concurrency=3)

    assert r_con.outputs == r_seq.outputs
    st = r_con.extras["scheduler"]
    assert st["max_verify_occupancy"] >= 2
    assert st["iterations"] < r_seq.extras["scheduler"]["iterations"]


def test_eight_streams_batching_efficiency(dev_nopi, eng8):
    """Acceptance criterion: 8 concurrent sessions on an 8-slot engine
    reach mean verify-iteration occupancy > 1.5 slots, take strictly
    fewer scheduler iterations than the 8 sequential runs combined, and
    emit identical greedy token streams."""
    prompts = _prompts(8)
    r_seq = SY.run_synera(dev_nopi, eng8, prompts, 16, concurrency=1)
    r_con = SY.run_synera(dev_nopi, eng8, prompts, 16, concurrency=8)

    assert r_con.outputs == r_seq.outputs
    st = r_con.extras["scheduler"]
    assert st["mean_verify_occupancy"] > 1.5
    assert st["iterations"] < r_seq.extras["scheduler"]["iterations"]
    # multi-tenant makespan beats back-to-back serving
    assert st["sim_ms"] < r_seq.extras["scheduler"]["sim_ms"]


def test_slot_reuse_across_staggered_arrivals(dev_nopi, eng2):
    """More sessions than engine slots, staggered arrivals: slots are
    released and reused without any cross-stream cache pollution
    (outputs stay identical to the sequential run)."""
    prompts = _prompts(4)
    r_seq = SY.run_synera(dev_nopi, eng2, prompts, 12, concurrency=1)

    server = SyneraServer(dev_nopi, eng2)
    metrics = server.serve(prompts, 12, concurrency=None,
                           arrivals=[0.0, 5.0, 900.0, 1800.0])
    assert [m.tokens for m in metrics] == r_seq.outputs
    used = [slot for s in server.sessions for slot in s.slots_used]
    assert set(used) <= {0, 1}
    assert len(used) == 4            # every session got (re)assigned a slot
    assert all(s.done for s in server.sessions)


def test_oversubscribed_concurrency_matches_sequential(dev_nopi, eng2):
    """4 concurrent sessions on 2 slots: late sessions park in wait_slot
    until a slot frees, and the token streams still match."""
    prompts = _prompts(4)
    r_seq = SY.run_synera(dev_nopi, eng2, prompts, 12, concurrency=1)
    r_con = SY.run_synera(dev_nopi, eng2, prompts, 12, concurrency=None)
    assert r_con.outputs == r_seq.outputs


def test_never_offloading_session_cancels_prefill(pair, eng2):
    """A stream that finishes without ever contacting the cloud again
    must cancel its fire-and-forget prompt prefill; otherwise the
    prefill later grabs a slot on behalf of a dead session and leaks it
    (stalling any stream parked in wait_slot)."""
    slm_cfg, slm_p, _, _ = pair
    dev_none = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                             policy=OffloadPolicy(mode="none"),
                             use_early_exit=False, use_pi=False)
    server = SyneraServer(dev_none, eng2)
    metrics = server.serve(_prompts(3), 8, concurrency=None)
    assert all(len(m.tokens) == 8 for m in metrics)
    assert len(server.sched.prefill_q) == 0     # cancelled, not leaked
    assert sorted(server.sched.free_slots) == [0, 1]
    assert all(s.done for s in server.sessions)


# deterministic no-compute engine speaking the fused interface — shared
# with the scheduler property tests so the stub cannot drift
from tests.test_scheduler_property import StubEngine as _StubEngine  # noqa: E402


def test_head_of_line_prefill_does_not_deadlock():
    """Regression: a queued prefill with no free slot must not starve
    pending verification work — verifies complete (eventually freeing
    slots) instead of the scheduler spinning on empty iterations."""
    eng = _StubEngine(max_slots=1)
    sched = VerificationAwareScheduler(eng, chunk=8)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 6)))
    evs = sched.run_iteration()
    assert [e.kind for e in evs] == ["prefill_done"]

    sched.submit_verify(VerifyRequest(2, 0, uncached=np.ones(3, np.int64),
                                      draft=np.ones(2, np.int64),
                                      q_sparse=None))
    sched.submit_prefill(PrefillRequest(3, np.arange(1, 4)))  # no free slot
    done = []
    for _ in range(10):
        done += sched.run_iteration()
        if any(e.kind == "verify_done" for e in done):
            break
    assert any(e.kind == "verify_done" and e.req_id == 2 for e in done)
    # the prefill is still parked (slot busy), not lost
    assert sched.has_work()
    sched.release_slot(0)
    evs = sched.run_iteration()
    assert [(e.kind, e.req_id) for e in evs] == [("prefill_done", 3)]


def test_arrival_gating_fast_forwards_clock():
    """A request with a future arrival is not served early: the idle
    scheduler fast-forwards its shared clock to the arrival instant."""
    eng = _StubEngine(max_slots=1)
    sched = VerificationAwareScheduler(eng, chunk=8)
    sched.submit_prefill(PrefillRequest(1, np.arange(1, 6),
                                        arrival_ms=250.0))
    assert sched.run_iteration() == []          # fast-forward only
    assert sched.sim_ms == 250.0
    evs = sched.run_iteration()
    assert [e.kind for e in evs] == ["prefill_done"]
    assert sched.sim_ms > 250.0
