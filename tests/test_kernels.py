"""Per-kernel correctness: sweep shapes/dtypes and assert_allclose
against the pure-jnp ref.py oracle (kernels run in interpret mode on
CPU; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn_importance.attn_importance import attn_with_importance
from repro.kernels.attn_importance.ref import attn_with_importance_ref
from repro.kernels.decode_gqa.decode_gqa import (decode_attention,
                                                 decode_attention_paged)
from repro.kernels.decode_gqa.ref import decode_attention_ref
from repro.kernels.partial_prefill.partial_prefill import (
    partial_prefill_attention, partial_prefill_attention_paged)
from repro.kernels.partial_prefill.ref import partial_prefill_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_sequential_ref

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=5e-2, rtol=5e-2)}


@pytest.mark.parametrize("B,T,S,nh,nkv,hd,causal", [
    (2, 64, 64, 4, 2, 32, True),
    (1, 100, 100, 8, 8, 64, True),      # non-divisible T (padding)
    (2, 32, 32, 4, 4, 16, False),
    (1, 16, 16, 8, 1, 32, True),        # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attn_importance(B, T, S, nh, nkv, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, hd), dtype)
    o1, i1 = attn_with_importance(q, k, v, causal=causal, block_q=32)
    o2, i2 = attn_with_importance_ref(q, k, v, causal=causal)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(i1), np.asarray(i2), atol=1e-3)
    # importance column sums over a causal matrix sum to ~Tq per head
    np.testing.assert_allclose(np.asarray(i1.sum(-1)),
                               np.full((B, nh), float(T)), rtol=1e-3)


def _cache_positions(rng, B, S, C):
    qp = np.zeros((B, C), np.int32)
    kp = np.full((B, S), -1, np.int32)
    for b in range(B):
        L = int(rng.integers(C + 1, S - C))
        kp[b, :L] = np.arange(L)
        nq = int(rng.integers(1, C + 1))
        qp[b, :nq] = L + np.arange(nq)
        qp[b, nq:] = -1
        kp[b, L:L + nq] = L + np.arange(nq)  # write-then-attend semantics
    return jnp.asarray(qp), jnp.asarray(kp)


@pytest.mark.parametrize("B,C,S,nh,nkv,hd,window", [
    (2, 8, 128, 4, 2, 32, 0),
    (1, 32, 100, 8, 8, 64, 0),
    (2, 4, 256, 4, 1, 16, 64),
    (3, 16, 96, 6, 3, 32, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_partial_prefill(B, C, S, nh, nkv, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, C, nh, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, hd), dtype)
    qp, kp = _cache_positions(np.random.default_rng(0), B, S, C)
    o1 = partial_prefill_attention(q, k, v, qp, kp, window=window,
                                   block_kv=64)
    o2 = partial_prefill_ref(q, k, v, qp, kp, window=window)
    mask = (np.asarray(qp) >= 0)[:, :, None, None]
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o1, np.float32) * mask,
                               np.asarray(o2, np.float32) * mask, **tol)


@pytest.mark.parametrize("B,S,nh,nkv,hd,window", [
    (2, 128, 8, 2, 32, 0),
    (1, 300, 4, 4, 64, 0),     # non-divisible S
    (3, 256, 8, 1, 16, 64),    # MQA + sliding window
    (2, 64, 16, 4, 32, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_gqa(B, S, nh, nkv, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, nh, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, hd), dtype)
    rng = np.random.default_rng(3)
    kp = np.full((B, S), -1, np.int32)
    qp = np.zeros(B, np.int32)
    for b in range(B):
        L = int(rng.integers(5, S))
        kp[b, :L] = np.arange(L)
        qp[b] = L - 1
    o1 = decode_attention(q, k, v, jnp.asarray(qp), jnp.asarray(kp),
                          window=window, block_kv=64)
    o2 = decode_attention_ref(q, k, v, jnp.asarray(qp), jnp.asarray(kp),
                              window=window)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# Block-table (paged) kernel variants: random block tables over a shared
# pool, dense oracle derived by gathering the pool through the tables.
# ---------------------------------------------------------------------------

def _random_paged_cache(rng, B, nb, bs, mbps, nkv, hd, lens, dtype):
    """Random pool + permuted tables backing ``lens[b]``-token slots,
    plus the gathered dense-equivalent view."""
    kp = jax.random.normal(jax.random.PRNGKey(7), (nb, bs, nkv, hd), dtype)
    vp = jax.random.normal(jax.random.PRNGKey(8), (nb, bs, nkv, hd), dtype)
    pos = np.full((nb, bs), -1, np.int32)
    bt = np.full((B, mbps), -1, np.int32)
    free = list(rng.permutation(nb))
    for b, L in enumerate(lens):
        for j in range(-(-L // bs)):
            blk = free.pop()
            bt[b, j] = blk
            valid = min(bs, L - j * bs)
            pos[blk, :valid] = j * bs + np.arange(valid)
    pos, bt = jnp.asarray(pos), jnp.asarray(bt)
    btc = jnp.where(bt < 0, nb, bt)
    s_max = mbps * bs
    kd = jnp.take(kp, btc, axis=0, mode="fill",
                  fill_value=0).reshape(B, s_max, nkv, hd)
    vd = jnp.take(vp, btc, axis=0, mode="fill",
                  fill_value=0).reshape(B, s_max, nkv, hd)
    posd = jnp.take(pos, btc, axis=0, mode="fill",
                    fill_value=-1).reshape(B, s_max)
    return kp, vp, pos, bt, kd, vd, posd


# (block_kv, kv_splits): unfused single-pass (the legacy layout), fused
# multi-block DMA, and fused + flash-decode split-KV.
PAGED_VARIANTS = [(None, 1), (128, 1), (64, 4)]


@pytest.mark.parametrize("B,nb,bs,mbps,nh,nkv,hd,window", [
    (3, 24, 8, 6, 4, 2, 32, 0),
    (2, 12, 16, 4, 8, 8, 64, 0),
    (2, 40, 8, 8, 4, 1, 16, 24),    # MQA + sliding window
])
@pytest.mark.parametrize("blkv,splits", PAGED_VARIANTS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_gqa_paged(B, nb, bs, mbps, nh, nkv, hd, window, blkv,
                          splits, dtype):
    rng = np.random.default_rng(11)
    lens = [int(rng.integers(2, mbps * bs)) for _ in range(B)]
    kp, vp, pos, bt, kd, vd, posd = _random_paged_cache(
        rng, B, nb, bs, mbps, nkv, hd, lens, dtype)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, nh, hd), dtype)
    qp = jnp.asarray([L - 1 for L in lens], jnp.int32)
    o1 = decode_attention_paged(q, kp, vp, qp, pos, bt, window=window,
                                block_kv=blkv, kv_splits=splits)
    o2 = decode_attention_ref(q, kd, vd, qp, posd, window=window)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,C,nb,bs,mbps,nh,nkv,hd,window", [
    (2, 8, 24, 8, 6, 4, 2, 32, 0),
    (1, 16, 12, 16, 4, 8, 8, 64, 0),
    (2, 4, 40, 8, 8, 4, 1, 16, 24),
])
@pytest.mark.parametrize("blkv,splits", PAGED_VARIANTS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_partial_prefill_paged(B, C, nb, bs, mbps, nh, nkv, hd, window,
                               blkv, splits, dtype):
    rng = np.random.default_rng(13)
    lens = [int(rng.integers(C + 1, mbps * bs)) for _ in range(B)]
    kp, vp, pos, bt, kd, vd, posd = _random_paged_cache(
        rng, B, nb, bs, mbps, nkv, hd, lens, dtype)
    q = jax.random.normal(jax.random.PRNGKey(10), (B, C, nh, hd), dtype)
    # chunk queries are the tail of each slot's sequence (already written
    # to the cache: write-then-attend semantics), ragged via -1 padding
    qp = np.full((B, C), -1, np.int32)
    for b in range(B):
        nq = int(rng.integers(1, C + 1))
        qp[b, :nq] = lens[b] - nq + np.arange(nq)
    qp = jnp.asarray(qp)
    o1 = partial_prefill_attention_paged(q, kp, vp, qp, pos, bt,
                                         window=window, block_kv=blkv,
                                         kv_splits=splits)
    o2 = partial_prefill_ref(q, kd, vd, qp, posd, window=window)
    mask = (np.asarray(qp) >= 0)[:, :, None, None]
    np.testing.assert_allclose(np.asarray(o1, np.float32) * mask,
                               np.asarray(o2, np.float32) * mask,
                               **TOL[dtype])


@pytest.mark.parametrize("kind", ["decode", "partial_prefill"])
def test_paged_split_kv_degenerates(kind):
    """Flash-decode split-KV: kv_splits in {1, 2, 4} agree with each
    other (combine epilogue is order-insensitive up to f32 rounding) and
    kv_splits=1 is the single-pass kernel — its combine is an exact
    no-op, so it matches the unfused default bit-for-bit when fuse=1."""
    rng = np.random.default_rng(17)
    B, nb, bs, mbps, nh, nkv, hd, C = 2, 24, 8, 6, 4, 2, 32, 8
    lens = [int(rng.integers(C + 1, mbps * bs)) for _ in range(B)]
    kp, vp, pos, bt, kd, vd, posd = _random_paged_cache(
        rng, B, nb, bs, mbps, nkv, hd, lens, jnp.float32)
    if kind == "decode":
        q = jax.random.normal(jax.random.PRNGKey(21), (B, nh, hd))
        qp = jnp.asarray([L - 1 for L in lens], jnp.int32)
        run = lambda sp, blkv=None: decode_attention_paged(
            q, kp, vp, qp, pos, bt, block_kv=blkv, kv_splits=sp)
        oracle = decode_attention_ref(q, kd, vd, qp, posd)
    else:
        q = jax.random.normal(jax.random.PRNGKey(22), (B, C, nh, hd))
        qp = jnp.asarray(np.stack([lens[b] - C + np.arange(C)
                                   for b in range(B)]), jnp.int32)
        run = lambda sp, blkv=None: partial_prefill_attention_paged(
            q, kp, vp, qp, pos, bt, block_kv=blkv, kv_splits=sp)
        oracle = partial_prefill_ref(q, kd, vd, qp, posd)
    base = run(1)
    # splits=1 degenerates exactly: same grid walk, no-op combine
    assert np.array_equal(np.asarray(run(1, blkv=bs)), np.asarray(base))
    for sp in (2, 4):
        o = run(sp)
        np.testing.assert_allclose(np.asarray(o), np.asarray(base),
                                   atol=2e-6, rtol=2e-6)
        np.testing.assert_allclose(np.asarray(o), np.asarray(oracle),
                                   **TOL[jnp.float32])


@pytest.mark.parametrize("B,L,H,P,N,chunk,use_h0", [
    (2, 64, 4, 16, 8, 16, False),
    (1, 50, 2, 32, 16, 16, True),     # non-divisible L (padding)
    (2, 128, 8, 8, 4, 32, False),
    (1, 33, 3, 8, 8, 8, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, L, H, P, N, chunk, use_h0, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = (jax.random.normal(ks[0], (B, L, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, L, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, L, N)) * 0.5).astype(dtype)
    h0 = (jax.random.normal(ks[5], (B, H, P, N)) * 0.2) if use_h0 else None
    y1, h1 = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    y2, h2 = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=tol["atol"] * 10, rtol=tol["rtol"] * 10)
    # against sequential ground truth (f32 only: bf16 accumulates)
    if dtype == jnp.float32:
        y3, h3 = ssd_sequential_ref(x, dt, A, Bm, Cm, h0=h0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), atol=1e-4,
                                   rtol=1e-4)
