"""Unified tracing layer tests (serving/trace.py).

Two families of invariants:

* **Attribution** — every completed stream's exclusive stall buckets
  sum exactly to its wall time (``bucket_sum == t_ms``), under plain
  serving and under forced preemption + host swap + mid-run replica
  death; ``Tracer.window_parts`` decomposes synthetic charge streams
  into the documented categories.

* **Passivity** — tracing must never change behavior: token streams
  are byte-identical with tracing on or off (property-tested over a
  shared-prefix + swap paged fleet), and the exported Chrome
  trace-event JSON passes the structural checker shipped in
  ``tools/check_trace.py``.
"""
import http.client
import json
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
from hypothesis import given, settings, strategies as st

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving import synergy as SY
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.gateway import protocol as P
from repro.serving.link import SimClock, Timeline
from repro.serving.router import ReplicaRouter
from repro.serving.server import WAIT_CLOUD, build_fleet
from repro.serving.trace import (NULL_TRACER, StreamTimeline, Tracer,
                                 hist_add, hist_from, hist_merge, hist_new)

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import check_trace  # noqa: E402  (tools/check_trace.py)


# ---------------------------------------------------------------------------
# Unit: StreamTimeline buckets
# ---------------------------------------------------------------------------

def _assert_sums(tl: StreamTimeline):
    assert abs(tl.bucket_sum - tl.t_ms) <= 1e-9 * max(1.0, tl.t_ms)


def test_timeline_is_link_alias():
    # serving/link.py's Timeline moved into serving/trace.py
    assert Timeline is StreamTimeline


def test_advance_kinds_map_to_buckets():
    tl = StreamTimeline()
    tl.advance(3.0, "compute")
    tl.advance(2.0, "comm")
    tl.advance(5.0, "stall")    # untraced stall -> other
    assert tl.compute_ms == 3.0 and tl.link_ms == 2.0
    assert tl.other_ms == 5.0 and tl.stall_ms == 5.0
    assert tl.t_ms == 10.0
    _assert_sums(tl)


def test_advance_stall_overlap_masks_front():
    # round trip: uplink 4 | cloud 8 | downlink 3 = 15; PI overlap 5
    # masks the front (all of uplink + 1ms of cloud); stall = 10 tail
    tl = StreamTimeline()
    tl.advance_stall(10.0, 4.0, [("cloud", 8.0)], 3.0, 5.0)
    assert tl.cloud_ms == pytest.approx(7.0)
    assert tl.link_ms == pytest.approx(3.0)
    assert tl.other_ms == pytest.approx(0.0)
    assert tl.t_ms == 10.0 and tl.stall_ms == 10.0
    _assert_sums(tl)


def test_advance_stall_without_parts_lands_in_other():
    tl = StreamTimeline()
    tl.advance_stall(7.5, 4.0, None, 3.0, 0.0)
    assert tl.other_ms == 7.5
    _assert_sums(tl)


def test_advance_stall_mixed_window_parts():
    # window contributed queue + other-stream wait + our cloud time
    tl = StreamTimeline()
    parts = [("queue", 2.0), ("wait", 3.0), ("cloud", 4.0)]
    tl.advance_stall(10.0, 0.5, parts, 0.5, 0.0)
    assert tl.link_ms == pytest.approx(1.0)
    assert tl.queue_ms == pytest.approx(2.0)
    assert tl.batch_wait_ms == pytest.approx(3.0)
    assert tl.cloud_ms == pytest.approx(4.0)
    _assert_sums(tl)


def test_advance_stall_caps_at_stall_total():
    # parts longer than the stall: buckets gain exactly stall_ms
    tl = StreamTimeline()
    tl.advance_stall(5.0, 0.0, [("cloud", 100.0)], 0.0, 0.0)
    assert tl.cloud_ms == pytest.approx(5.0)
    _assert_sums(tl)


# ---------------------------------------------------------------------------
# Unit: histogram helpers + Prometheus exposition
# ---------------------------------------------------------------------------

def test_hist_cumulative_semantics():
    h = hist_from([7.0, 30.0, 99999.0])
    # 7 <= 10, 25-bucket counts 7; all finite buckets cumulative
    le = h["le"]
    assert h["buckets"][le.index(5.0)] == 0
    assert h["buckets"][le.index(10.0)] == 1
    assert h["buckets"][le.index(50.0)] == 2
    assert h["buckets"][-1] == 3 == h["count"]   # +Inf
    assert h["sum"] == pytest.approx(7.0 + 30.0 + 99999.0)
    m = hist_merge([h, hist_from([8.0])])
    assert m["count"] == 4
    assert m["buckets"][le.index(10.0)] == 2


def test_metrics_text_renders_histograms():
    stats = {"completed_streams": 3, "trace": True,
             "hist_ttft_ms": hist_from([7.0, 600.0])}
    text = P.metrics_text(stats)
    assert "synera_completed_streams 3" in text
    assert "synera_trace 1" in text
    assert "# TYPE synera_ttft_ms histogram" in text
    assert 'synera_ttft_ms_bucket{le="10"} 1' in text
    assert 'synera_ttft_ms_bucket{le="1000"} 2' in text
    assert 'synera_ttft_ms_bucket{le="+Inf"} 2' in text
    assert "synera_ttft_ms_count 2" in text
    assert "synera_ttft_ms_sum 607.0" in text


# ---------------------------------------------------------------------------
# Unit: window decomposition
# ---------------------------------------------------------------------------

def test_window_parts_categories():
    tr = Tracer(SimClock())
    tr.span(0.0, 10.0, "prefill", rids=(1,))          # our prompt prefill
    tr.span(10.0, 20.0, "verify", rids=(2,))          # our verify (rewound)
    tr.span(20.0, 30.0, "verify", rids=(3,))          # another stream
    tr.span(30.0, 35.0, "swap_out", slot=0)           # our slot swapped
    tr.instant("rewind", t=30.0, rids=(2,))           # we got preempted
    tr.span(35.0, 45.0, "verify", rids=(2,))          # re-served after
    parts = tr.window_parts(0.0, 45.0, slot=0, vrid=2, prefill_rid=1)
    # serving spans that ended before the rewind were thrown away
    assert parts == [("preempted", 20.0), ("wait", 10.0),
                     ("swap", 5.0), ("cloud", 10.0)]
    assert sum(d for _, d in parts) == pytest.approx(45.0)


def test_window_parts_queue_before_own_prefill():
    tr = Tracer(SimClock())
    tr.span(0.0, 10.0, "verify", rids=(9,))     # other stream ahead of us
    tr.span(10.0, 20.0, "prefill", rids=(1,))   # our prompt prefill
    tr.span(20.0, 30.0, "verify", rids=(2,))
    parts = tr.window_parts(0.0, 30.0, vrid=2, prefill_rid=1)
    assert parts == [("queue", 10.0), ("cloud", 20.0)]


def test_window_parts_uncovered_residual_is_other():
    tr = Tracer(SimClock())
    tr.span(0.0, 4.0, "verify", rids=(2,))
    parts = tr.window_parts(0.0, 10.0, vrid=2)
    assert parts == [("cloud", 4.0), ("other", 6.0)]


def test_window_parts_respects_replica_tag():
    tr = Tracer(SimClock())
    tr.span(0.0, 10.0, "verify", replica=1, rids=(2,))
    # same rid on another replica is someone else's request
    parts = tr.window_parts(0.0, 10.0, replica=0, vrid=2)
    assert parts == [("wait", 10.0)]


def test_null_tracer_is_inert():
    assert not NULL_TRACER and not NULL_TRACER.enabled
    assert NULL_TRACER.stream_begin("s", 0.0) == -1
    assert NULL_TRACER.window_parts(0.0, 1.0) is None
    NULL_TRACER.span(0, 1, "x")
    NULL_TRACER.instant("x")
    with pytest.raises(RuntimeError):
        NULL_TRACER.export("/tmp/never.json")


# ---------------------------------------------------------------------------
# Integration: serving runs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


def _mk_engine(pair, **kw):
    _, _, llm_cfg, llm_p = pair
    kw.setdefault("cache_impl", "paged")
    kw.setdefault("block_size", 16)
    kw.setdefault("share_prefix", True)
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256, **kw)


def _prompts(n, length=8, shared=0, seed=5):
    rng = np.random.default_rng(seed)
    common = [int(t) for t in rng.integers(1, 60, 16)]
    out = []
    for i in range(n):
        suffix = [int(t) for t in rng.integers(1, 60, length)]
        out.append((common if i < shared else []) + suffix)
    return out


def _tokens(metrics):
    return [[int(t) for t in m.tokens] for m in metrics]


def test_traced_run_buckets_sum_and_byte_identity(dev, pair, tmp_path):
    prompts = _prompts(4, shared=4, seed=3)
    base = SY.run_synera(dev, _mk_engine(pair), prompts, 8, concurrency=4)
    res = SY.run_synera(dev, _mk_engine(pair), prompts, 8, concurrency=4,
                        trace=True)
    assert res.outputs == base.outputs            # tracing is passive
    for m in res.metrics:
        _assert_sums(m.timeline)
    sched = res.extras["scheduler"]
    assert sched["trace"] is True
    assert sched["stall_wall_ms"] == pytest.approx(
        sum(m.timeline.t_ms for m in res.metrics))
    assert sched["stall_wall_ms"] == pytest.approx(
        sched["stall_device_ms"] + sched["stall_cloud_ms"]
        + sched["stall_link_ms"] + sched["stall_queue_ms"]
        + sched["stall_batch_wait_ms"] + sched["stall_swap_ms"]
        + sched["stall_preempted_ms"] + sched["stall_other_ms"])
    assert sched["hist_e2e_ms"]["count"] == len(prompts)
    # exported trace passes the structural + bucket-sum checker
    out = tmp_path / "trace.json"
    res.extras["tracer"].export(str(out))
    errors, summary = check_trace.check_file(str(out),
                                             min_streams=len(prompts))
    assert errors == [], errors
    assert summary["buckets_checked"] == len(prompts)


def test_untraced_stats_carry_no_stall_attribution(dev, pair):
    res = SY.run_synera(dev, _mk_engine(pair), _prompts(2), 4,
                        concurrency=2)
    sched = res.extras["scheduler"]
    assert sched["trace"] is False
    # with tracing off the stall portion is unattributed by design
    assert sched["stall_cloud_ms"] == 0.0
    assert sched["stall_queue_ms"] == 0.0


def test_fleet_pressure_buckets_sum(dev, pair):
    """Preemption + host swap + mid-run replica kill: every surviving
    stream's buckets still sum to its wall time, and the pressure
    actually shows up in the swap/preempted/queue buckets."""
    n, max_new = 6, 12
    prompts = _prompts(n, length=12, seed=11)
    engines = [_mk_engine(pair, block_size=4, pool_blocks=24, swap=True)
               for _ in range(2)]
    clock = SimClock()
    tracer = Tracer(clock)
    router = ReplicaRouter(
        build_fleet(dev, engines, clock=clock, tracer=tracer),
        policy="round-robin")
    sess = [router.open_session(p, max_new) for p in prompts]
    for _ in range(400):
        router.step()
        if any(s.state == WAIT_CLOUD
               for s in router.replicas[0].sessions if not s.done):
            break
    else:
        pytest.fail("replica 0 never reached a mid-verify state")
    router.kill_replica(0)
    while router.step():
        pass
    assert all(s.done for s in sess)
    for s in sess:
        _assert_sums(s.metrics.timeline)
    stats = router.stats()
    assert stats["dead_replicas"] == 1
    assert stats["completed_streams"] == n
    assert stats["stall_wall_ms"] == pytest.approx(
        sum(s.metrics.timeline.t_ms for s in sess))
    pressured = (stats["stall_swap_ms"] + stats["stall_preempted_ms"]
                 + stats["stall_queue_ms"] + stats["stall_batch_wait_ms"])
    assert pressured > 0.0
    # the trace records the fleet events end-to-end
    kinds = {k for _, k, *_ in tracer._instants}
    assert "replica_kill" in kinds
    # rerouted streams carry a per-stream "reroute" marker
    snames = {nm for rec in tracer._streams.values()
              for nm, _, _ in rec.instants}
    assert "reroute" in snames


def test_degraded_streams_fold_into_fleet_stats(dev, pair):
    """Device-only degraded sessions belong to no replica; their
    buckets and latency samples still land in the aggregate view."""
    prompts = _prompts(3, seed=17)
    res = SY.run_synera_fleet(dev, [_mk_engine(pair)], prompts, 6,
                              policy="round-robin", replica_queue_cap=1,
                              concurrency=3, trace=True)
    sched = res.extras["scheduler"]
    assert sched["degraded_streams"] >= 1
    assert sched["completed_streams"] == len(prompts)
    assert sched["hist_e2e_ms"]["count"] == len(prompts)
    assert sched["stall_wall_ms"] == pytest.approx(
        sum(m.timeline.t_ms for m in res.metrics))
    for m in res.metrics:
        _assert_sums(m.timeline)


def _http(port, method, path, obj=None):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=180)
    try:
        body = json.dumps(obj) if obj is not None else None
        conn.request(method, path, body,
                     {"Content-Type": "application/json"} if body else {})
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def test_gateway_traces_endpoint_and_metrics_histograms(dev, pair):
    """/v1/traces snapshots a Perfetto-loadable trace mid-flight and
    /metrics exposes the tracer-fed latency histograms."""
    from repro.serving.gateway import Gateway, GatewayConfig
    from repro.serving.link import RealClock
    from repro.serving.server import SyneraServer
    eng = _mk_engine(pair)
    clock = RealClock()
    server = SyneraServer(dev, eng, clock=clock, clamp_arrivals=True,
                          tracer=Tracer(clock))
    gw = Gateway(server, GatewayConfig(port=0, max_new_default=4)).start()
    try:
        status, body = _http(gw.port, "POST", "/v1/chat/completions",
                             {"messages": [{"role": "user",
                                            "content": "3 17 42 9"}],
                              "max_tokens": 4})
        assert status == 200, body
        status, body = _http(gw.port, "GET", "/v1/traces")
        assert status == 200
        doc = json.loads(body)
        assert doc["enabled"] is True
        errors, summary = check_trace.check_events(doc["traceEvents"])
        assert errors == [], errors
        assert summary["streams"] >= 1
        assert summary["buckets_checked"] >= 1
        status, body = _http(gw.port, "GET", "/metrics")
        assert status == 200
        text = body.decode()
        assert "# TYPE synera_ttft_ms histogram" in text
        assert 'synera_e2e_ms_bucket{le="+Inf"}' in text
        assert "synera_stall_wall_ms" in text
    finally:
        gw.close()
    # a gateway without --trace reports the endpoint as disabled
    server2 = SyneraServer(dev, eng, clock=RealClock(),
                           clamp_arrivals=True)
    gw2 = Gateway(server2, GatewayConfig(port=0)).start()
    try:
        status, body = _http(gw2.port, "GET", "/v1/traces")
        assert status == 200
        assert json.loads(body)["enabled"] is False
    finally:
        gw2.close()


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=2, max_value=10),
       st.integers(min_value=1, max_value=4))
def test_tracing_byte_identity_property(dev, pair, n, max_new, conc):
    """Tracing on/off never changes token streams, across stream
    counts / lengths / concurrency on a shared-prefix + swap fleet."""
    prompts = _prompts(n, shared=n, seed=100 + n + max_new)
    kw = dict(block_size=4, pool_blocks=32, swap=True)
    base = SY.run_synera_fleet(
        dev, [_mk_engine(pair, **kw) for _ in range(2)], prompts, max_new,
        policy="prefix-affinity", concurrency=conc)
    traced = SY.run_synera_fleet(
        dev, [_mk_engine(pair, **kw) for _ in range(2)], prompts, max_new,
        policy="prefix-affinity", concurrency=conc, trace=True)
    assert traced.outputs == base.outputs
    for m in traced.metrics:
        _assert_sums(m.timeline)
