"""Substrate tests: synthetic data pipeline, optimizer, checkpointing,
HLO analyzer, link/cost models."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt
from repro.data.synthetic import SyntheticTask, TaskSpec, batches
from repro.optim.adamw import AdamW, clip_by_global_norm, cosine_schedule
from repro.serving.link import (CloudLatencyModel, CostModel,
                                DeviceLatencyModel, LinkModel)


class TestSyntheticTask:
    def setup_method(self):
        self.task = SyntheticTask(TaskSpec(vocab=64))

    def test_true_dist_is_distribution(self):
        rng = np.random.default_rng(0)
        seq, regimes = self.task.sample_sequence(128, rng)
        for t in [1, 7, 16, 63, 64, 100]:
            p = self.task.true_dist(seq, t, regimes)
            assert abs(p.sum() - 1.0) < 1e-9
            assert (p >= 0).all()

    def test_copy_rule_deterministic(self):
        rng = np.random.default_rng(1)
        seq, regimes = self.task.sample_sequence(128, rng)
        sp = self.task.spec
        for t in range(sp.copy_back, 128):
            if t % sp.copy_every == 0 and t % sp.regime_len != 0:
                assert seq[t] == seq[t - sp.copy_back]

    def test_score_perfect_continuation(self):
        rng = np.random.default_rng(2)
        seq, regimes = self.task.sample_sequence(128, rng)
        s = self.task.score(seq, regimes, start=64)
        assert s["copy_acc"] == 1.0
        assert s["quality"] > 0.1  # true continuation has decent likelihood

    def test_score_random_continuation_worse(self):
        rng = np.random.default_rng(3)
        seq, regimes = self.task.sample_sequence(128, rng)
        good = self.task.score(seq, regimes, 64)
        bad_seq = seq.copy()
        bad_seq[64:] = rng.integers(0, 60, size=64)
        bad = self.task.score(bad_seq, regimes, 64)
        assert good["quality"] > bad["quality"]

    def test_batches_shape(self):
        corpus, _ = self.task.corpus(4, 512, seed=0)
        it = batches(corpus, 8, 64, rng=np.random.default_rng(0))
        b = next(it)
        assert b.shape == (8, 64)
        assert b.max() < 64


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.update(g, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
        assert float(gn) == pytest.approx(200.0)

    def test_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(5)) == pytest.approx(0.5)
        assert float(lr(10)) == pytest.approx(1.0, abs=0.02)
        assert float(lr(100)) == pytest.approx(0.1, abs=0.02)

    def test_state_dtype(self):
        opt = AdamW(state_dtype=jnp.bfloat16)
        st = opt.init({"w": jnp.zeros((3,))})
        assert st.mu["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        p = str(tmp_path / "ck.npz")
        ckpt.save(p, tree)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        back = ckpt.load(p, like)
        for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


class TestHloAnalysis:
    def test_scan_flops_exact(self):
        from repro.launch.hlo_analysis import analyze

        def f(x, w):
            def body(c, ww):
                return jnp.tanh(c @ ww), None
            y, _ = jax.lax.scan(body, x, w)
            return y.sum()
        co = jax.jit(f).lower(jnp.ones((8, 16)), jnp.ones((5, 16, 16))).compile()
        r = analyze(co.as_text())
        assert r["flops"] == pytest.approx(5 * 2 * 8 * 16 * 16, rel=0.01)
        assert 5 in r["trip_counts"]
        assert r["unresolved_dots"] == 0


class TestLinkModels:
    def test_transfer_scales_with_bytes(self):
        link = LinkModel(bandwidth_mbps=8.0, rtt_ms=0.0)
        assert link.transfer_ms(1_000_000) == pytest.approx(1000.0)

    @given(st.floats(0.01, 1.0), st.floats(1.0, 1000.0))
    @settings(max_examples=20, deadline=None)
    def test_cost_monotone(self, frac, tbt):
        cm = CostModel(packing_factor=13)
        assert cm.cost(tbt, frac) <= cm.cost(tbt, min(frac * 2, 1.0)) + 1e-9

    def test_early_exit_saves_latency_and_energy(self):
        d = DeviceLatencyModel()
        assert d.draft_ms(4, 0.75) < d.draft_ms(4, 1.0)
        assert d.energy_j(4, 0.75) < d.energy_j(4, 1.0)


class TestQuantize:
    def test_fake_quant_error_bounded(self):
        from repro.optim.quantize import fake_quant
        import jax
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        for bits, tol in ((8, 0.02), (4, 0.3)):
            wq = fake_quant(w, bits)
            err = float(jnp.abs(wq - w).max())
            assert err < tol, (bits, err)

    def test_quantize_params_preserves_structure(self):
        from repro.optim.quantize import quantize_params
        from repro.configs.synera_pair import tiny_pair
        from repro.models import model as M
        import jax
        cfg, _ = tiny_pair(vocab=32)
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        q = quantize_params(p, 8)
        assert jax.tree.structure(p) == jax.tree.structure(q)
        # norms untouched, projections changed
        assert (q["final_norm"] == p["final_norm"]).all()
        l0 = jax.tree.map(lambda x: x[0], p["layers"])
        q0 = jax.tree.map(lambda x: x[0], q["layers"])
        assert float(jnp.abs(q0["attn"]["wq"] - l0["attn"]["wq"]).max()) > 0
