"""Sharding-rule unit tests + an in-subprocess reduced dry-run on a small
forced-host-device mesh (jax locks the device count at init, so the mesh
test must run in a fresh interpreter)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import params_specs


@pytest.fixture(scope="module")
def mesh():
    # uses the real single CPU device grid (1x1): rules must degrade to
    # full replication without error
    return make_host_mesh(data=1, model=1)


def test_param_specs_cover_all_archs(mesh):
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        avals = params_specs(cfg)
        sh = SH.params_shardings(mesh, cfg, avals, mode="train")
        flat = jax.tree.leaves(sh)
        assert len(flat) == len(jax.tree.leaves(avals))


def test_divisibility_fallback(mesh):
    # glm4 has 2 kv heads: wk/wv output dim (2*128=256) not divisible by a
    # 16-way model axis -> must replicate, never raise
    cfg = get_config("glm4-9b")
    avals = params_specs(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(avals)
    for path, leaf in flat:
        spec = SH.param_spec(path, leaf, mesh, cfg, mode="train")
        assert isinstance(spec, P)


DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from repro.launch.mesh import make_host_mesh
    from repro.launch import sharding as SH
    from repro.launch.specs import params_specs, input_specs
    from repro.launch.hlo_analysis import analyze
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES, InputShape
    from repro.models import model as M
    from repro.models.steps import make_decode_step, make_train_step
    from repro.optim.adamw import AdamW

    arch = %r
    cfg = get_config(arch).reduced().replace(dtype="bfloat16", remat=True)
    mesh = make_host_mesh(data=2, model=4)
    p_avals = params_specs(cfg)
    p_shard = SH.params_shardings(mesh, cfg, p_avals, mode="train")
    opt = AdamW()
    o_avals = jax.eval_shape(opt.init, p_avals)
    o_shard = type(o_avals)(
        step=SH.NamedSharding(mesh, SH.P()),
        mu=SH.params_shardings(mesh, cfg, o_avals.mu),
        nu=SH.params_shardings(mesh, cfg, o_avals.nu))
    B, T = 4, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.vision_dim), jnp.bfloat16)
    if cfg.family == "audio":
        batch["audio_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    b_shard = SH.batch_shardings(mesh, batch)
    fn = make_train_step(cfg, opt)
    co = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                 out_shardings=(p_shard, o_shard, None)).lower(
        p_avals, o_avals, batch).compile()
    r = analyze(co.as_text())
    print(json.dumps({"ok": True, "flops": r["flops"],
                      "coll": r["collective_bytes"]}))
""")


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-moe-235b-a22b",
                                  "mamba2-2.7b", "zamba2-2.7b",
                                  "whisper-medium", "llama-3.2-vision-90b"])
def test_reduced_dryrun_on_host_mesh(arch):
    """Reduced config lowers + compiles on a 2x4 host-device mesh with the
    production sharding rules (one family representative each)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET % arch],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
