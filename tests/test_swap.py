"""Host-memory KV swap tier + policy-driven preemption (ISSUE 5).

The headline property: with ``swap=True`` and a pool forced dry,
greedy token streams are byte-identical to the non-preempted dense and
paged runs under all three victim policies (``youngest``,
``most-blocks``, ``slo-aware``) — the swapped blocks are restored
bit-for-bit, so preemption disposition can never change outputs, only
modeled time.

Layers covered:

* ``HostSwapManager`` units — plan (shared-lead detection, host
  capacity), swap-out freeing exactly the unshared blocks, swap-in
  re-adoption of a still-shared lead, swap-in degradation when the
  share expired (the sibling died while the victim was on the host);
* disposition policy — a crippled host link makes recompute the
  modeled winner (swap enabled but unused), a tiny host store forces
  the recompute fallback;
* serving-level identity across {no-preemption, recompute, swap} and
  across victim policies (hypothesis property), forced
  swap-out-while-shared, and the scheduler-level share-expiry rewind.

Engines are module-scoped fixtures (jitted steps are expensive to
recompile; released slots are fully reset and the swap store drains
with its sessions, so reuse is safe).
"""
import numpy as np
import pytest

import jax

from hypothesis import given, settings, strategies as st

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.link import CloudLatencyModel
from repro.serving.scheduler import (PrefillRequest, VerifyRequest,
                                     VerificationAwareScheduler)
from repro.serving.swap import PREEMPT_POLICIES, StreamSLO
from repro.serving import synergy as SY

S_MAX = 256


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=S_MAX, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


@pytest.fixture(scope="module")
def eng_dense(pair):
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX)


@pytest.fixture(scope="module")
def eng_recompute(pair):
    """Tight pool, no swap tier: recompute-eviction under pressure."""
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                       cache_impl="paged", block_size=4, pool_blocks=11)


@pytest.fixture(scope="module")
def eng_swap(pair):
    """Same tight pool with the host swap tier enabled."""
    _, _, llm_cfg, llm_p = pair
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                       cache_impl="paged", block_size=4, pool_blocks=11,
                       swap=True)


def _prompts(lens, seed=5):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 60, size=max(L, 2))]
            for L in lens]


def _drained(eng):
    assert eng.allocator.used_blocks == 0
    if eng.swap_manager is not None:
        assert eng.swap_manager.swapped_blocks == 0


# ---------------------------------------------------------------------------
# Engine/manager units
# ---------------------------------------------------------------------------

def _prefill_slot(eng, slot, P):
    B = eng.max_slots
    m = eng.alloc_prompt(slot, P)
    t = np.zeros((B, len(P)), np.int32)
    p = np.full((B, len(P)), -1, np.int32)
    t[slot, m:] = P[m:]
    p[slot, m:] = np.arange(m, len(P))
    eng.prefill(t, p)
    return m


def test_swap_requires_paged(pair):
    _, _, llm_cfg, llm_p = pair
    with pytest.raises(ValueError, match="paged"):
        CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64, swap=True)


def test_manager_roundtrip_restores_bit_identical(pair):
    """Swap a slot out and back in; a decode afterwards matches a
    never-swapped engine bit-for-bit (the pool content was restored
    exactly, through fresh blocks)."""
    _, _, llm_cfg, llm_p = pair

    def mk(swap):
        return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                           cache_impl="paged", block_size=4, swap=swap)

    P = _prompts([12], seed=3)[0]
    outs = []
    for swap in (True, False):
        eng = mk(swap)
        _prefill_slot(eng, 0, P)
        if swap:
            sw = eng.swap_manager
            assert sw.plan(0) == (0, 3, 3 * eng.block_bytes())
            moved = sw.swap_out(0, P, len(P))
            assert moved == 3 * eng.block_bytes()
            assert eng.allocator.used_blocks == 0
            assert sw.swapped_blocks == 3
            assert sw.swap_in(0) == (len(P), moved)
            assert sw.swapped_blocks == 0
            assert eng.allocator.used_blocks == 3
        td = np.zeros((2, 1), np.int32)
        pd = np.full((2, 1), -1, np.int32)
        td[0, 0], pd[0, 0] = 5, len(P)
        outs.append(eng.decode(td, pd))
        eng.reset_slot(0)
        _drained(eng)
    assert np.array_equal(outs[0].token_id[0], outs[1].token_id[0])
    assert np.array_equal(outs[0].topk_idx[0], outs[1].topk_idx[0])
    assert np.array_equal(outs[0].topk_val[0], outs[1].topk_val[0])


def test_manager_shared_lead_drops_ref_and_readopts(pair):
    """Swapping a victim that rides on shared blocks never moves them:
    the victim drops its reference (the sibling keeps reading them) and
    re-adopts from the index at swap-in."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                      cache_impl="paged", block_size=4, share_prefix=True,
                      swap=True)
    a, sw = eng.allocator, eng.swap_manager
    P = _prompts([12], seed=7)[0]
    _prefill_slot(eng, 0, P)
    # adopts 2 leading blocks + 3 tail rows of the third (len-1 cap)
    m1 = _prefill_slot(eng, 1, P)
    assert m1 == 11 and a.shared_blocks == 2
    lead, n_swap, _ = sw.plan(1)
    assert (lead, n_swap) == (2, 1)        # only the private tail moves
    used0 = a.used_blocks
    sw.swap_out(1, P, len(P))
    assert a.used_blocks == used0 - 1      # shared lead stayed in-pool
    assert all(int(a.ref[int(a.table[0, j])]) == 1 for j in range(2))
    frontier, _ = sw.swap_in(1)
    assert frontier == len(P)
    assert a.shared_blocks == 2            # lead re-adopted (ref back to 2)
    eng.reset_slot(0)
    eng.reset_slot(1)
    _drained(eng)


def test_manager_swap_in_after_share_expired(pair):
    """If the sibling dies while the victim is on the host, the shared
    lead leaves the prefix index with it — swap-in must report the
    expiry (None) instead of restoring a stream missing its prefix."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                      cache_impl="paged", block_size=4, share_prefix=True,
                      swap=True)
    sw = eng.swap_manager
    P = _prompts([12], seed=9)[0]
    _prefill_slot(eng, 0, P)
    assert _prefill_slot(eng, 1, P) == 11  # 2 blocks + 3 tail rows
    sw.swap_out(1, P, len(P))
    eng.reset_slot(0)                      # sibling dies: share expires
    assert sw.swap_in(1) is None
    assert sw.expired_shares == 1
    assert sw.swapped_blocks == 0          # payload dropped
    _drained(eng)


def test_manager_host_capacity_gates_swap(pair):
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=64,
                      cache_impl="paged", block_size=4, swap=True,
                      host_swap_blocks=2)
    sw = eng.swap_manager
    P = _prompts([12], seed=11)[0]         # 3 blocks > capacity 2
    _prefill_slot(eng, 0, P)
    assert sw.plan(0) is None
    assert sw.swap_out(0, P, len(P)) is None
    eng.reset_slot(0)
    _drained(eng)


# ---------------------------------------------------------------------------
# Scheduler-level share expiry (degrade to recompute)
# ---------------------------------------------------------------------------

def test_scheduler_rewinds_on_share_expiry(pair):
    """_swap_in_ready: a swapped stream whose shared lead expired is
    rewound (frontier 0, pending requests refeed from scratch) and
    counted, instead of being restored with a hole in its prefix."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=64,
                      cache_impl="paged", block_size=4, share_prefix=True,
                      swap=True)
    sched = VerificationAwareScheduler(eng, chunk=8)
    P = _prompts([12], seed=13)[0]
    sched.submit_prefill(PrefillRequest(1, np.asarray(P)))
    sched.submit_prefill(PrefillRequest(2, np.asarray(P)))
    evs = sched.run_iteration()
    slots = {e.req_id: e.slot for e in evs}
    victim = slots[2]
    # evict the adopter to the host, then kill the sibling
    moved = eng.swap_manager.swap_out(victim, P,
                                      int(sched.cloud_len[victim]))
    assert moved is not None
    assert sched._slot_swapped(victim)
    sched.release_slot(slots[1])
    # a pending verify request for the swapped stream
    seq = np.asarray(P + [7, 8], np.int64)
    req = VerifyRequest(3, victim, uncached=seq[len(P):], draft=seq[-1:],
                        q_sparse=[], seq=seq)
    req.start_pos = int(sched.cloud_len[victim])
    sched.verify_q.append(req)
    sched._swap_in_ready()
    assert sched.swap_expirations == 1
    assert not sched._slot_swapped(victim)
    assert int(sched.cloud_len[victim]) == 0
    assert req.start_pos == 0 and req.fed == 0
    assert np.array_equal(req.uncached, seq)   # from-scratch partial prefill
    sched.release_slot(victim)
    _drained(eng)


def test_admission_reserves_blocks_for_swapped_head(pair):
    """Fresh prompt admissions must not consume the blocks a waiting
    swapped stream needs to return — otherwise a continuous arrival
    stream could eat every freed block the moment it appears and
    starve the swapped stream indefinitely."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=4, s_max=64,
                      cache_impl="paged", block_size=4, pool_blocks=9,
                      swap=True)
    a = eng.allocator
    sched = VerificationAwareScheduler(eng, chunk=8)
    P = _prompts([16, 12, 8], seed=19)
    sched.submit_prefill(PrefillRequest(1, np.asarray(P[0])))  # 4 blocks
    sched.submit_prefill(PrefillRequest(2, np.asarray(P[1])))  # 3 blocks
    evs = sched.run_iteration()
    slots = {e.req_id: e.slot for e in evs}
    # park stream 2 on the host (needs 3 blocks to come back) ...
    assert eng.swap_manager.swap_out(slots[2], P[1],
                                     int(sched.cloud_len[slots[2]])) \
        is not None
    # ... and let stream 1 grow into the freed space (verify growth),
    # leaving 2 free: NOT enough for the head to return
    assert a.extend(slots[1], 28)
    eng._tables_dirty = True
    eng._sync_tables()
    assert a.free_blocks == 2
    assert sched._swap_in_reserve() == 3
    # a fresh 2-block prompt WOULD fit the 2 free blocks, but they are
    # spoken for: it must queue, not starve the swapped head
    sched.submit_prefill(PrefillRequest(3, np.asarray(P[2])))
    assert sched.run_iteration() == []
    assert len(sched.prefill_q) == 1
    assert sched._slot_swapped(slots[2])
    # stream 1 exits: the head returns FIRST, then the prompt admits
    sched.release_slot(slots[1])
    evs = sched.run_iteration()
    assert not sched._slot_swapped(slots[2])
    assert int(sched.cloud_len[slots[2]]) == len(P[1])
    assert [e.req_id for e in evs] == [3]
    sched.release_slot(slots[2])
    for s in range(eng.max_slots):
        if a.n_blocks_of[s] > 0:
            sched.release_slot(s)
    _drained(eng)


# ---------------------------------------------------------------------------
# Disposition policy
# ---------------------------------------------------------------------------

def test_slow_host_link_prefers_recompute(dev, eng_dense, pair):
    """The disposition is a modeled-cost comparison, not a hard switch:
    with a crippled host link the D2H+H2D round trip loses to the
    re-prefill and the scheduler recomputes even though swap is on."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                      cache_impl="paged", block_size=4, pool_blocks=11,
                      swap=True)
    lat = CloudLatencyModel(host_link_gbps=1e-7)   # ~10 s per KB
    prompts = _prompts([8, 8, 8, 8], seed=29)
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r = SY.run_synera(dev, eng, prompts, 12, concurrency=4, latency=lat)
    assert r.outputs == r_ref.outputs
    st_ = r.extras["scheduler"]
    assert st_["swap_evictions"] == 0
    assert st_["recompute_evictions"] >= 1
    _drained(eng)


def test_tiny_host_store_falls_back_to_recompute(dev, eng_dense, pair):
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                      cache_impl="paged", block_size=4, pool_blocks=11,
                      swap=True, host_swap_blocks=1)
    prompts = _prompts([8, 8, 8, 8], seed=29)
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r = SY.run_synera(dev, eng, prompts, 12, concurrency=4)
    assert r.outputs == r_ref.outputs
    st_ = r.extras["scheduler"]
    assert st_["swap_evictions"] == 0 and st_["recompute_evictions"] >= 1
    _drained(eng)


# ---------------------------------------------------------------------------
# Serving-level acceptance
# ---------------------------------------------------------------------------

def test_swap_recovers_stream_without_refeeding(dev, eng_dense, eng_swap,
                                                eng_recompute):
    """ISSUE 5 acceptance: a pool forced dry serves identically under
    recompute and swap, and swap refeeds (far) fewer tokens."""
    prompts = _prompts([8, 8, 8, 8], seed=29)
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r_re = SY.run_synera(dev, eng_recompute, prompts, 12, concurrency=4)
    r_sw = SY.run_synera(dev, eng_swap, prompts, 12, concurrency=4)
    assert r_re.outputs == r_ref.outputs
    assert r_sw.outputs == r_ref.outputs
    st_re = r_re.extras["scheduler"]
    st_sw = r_sw.extras["scheduler"]
    assert st_re["recompute_evictions"] >= 1 and st_re["swap_evictions"] == 0
    assert st_sw["swap_evictions"] >= 1
    assert st_sw["swap_out_bytes"] > 0
    assert st_sw["swap_in_bytes"] == st_sw["swap_out_bytes"]
    # the whole point: swapped streams come back without refeeding
    assert (st_sw["preempted_refed_tokens"]
            < st_re["preempted_refed_tokens"])
    _drained(eng_swap)
    _drained(eng_recompute)


def test_swap_while_shared_preserves_identity(dev, eng_dense, pair):
    """Forced swap-out of a stream riding on shared prefix blocks: the
    sibling keeps its blocks, the victim re-adopts on swap-in, outputs
    stay byte-identical to dense."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=S_MAX,
                      cache_impl="paged", block_size=4, pool_blocks=11,
                      share_prefix=True, swap=True)
    rng = np.random.default_rng(31)
    common = [int(t) for t in rng.integers(1, 60, 8)]
    prompts = [common + [int(t) for t in rng.integers(1, 60, 4)]
               for _ in range(4)]
    r_ref = SY.run_synera(dev, eng_dense, prompts, 12, concurrency=1)
    r = SY.run_synera(dev, eng, prompts, 12, concurrency=4)
    assert r.outputs == r_ref.outputs
    st_ = r.extras["scheduler"]
    assert st_["swap_evictions"] >= 1
    assert st_["dedupe_hit_blocks"] >= 1
    _drained(eng)


def test_slo_aware_spares_tight_deadline(pair, dev):
    """slo-aware victim selection: under pressure the stream with the
    most remaining slack (here: no SLO at all) is evicted, never the
    one racing a deadline."""
    _, _, llm_cfg, llm_p = pair
    eng = CloudEngine(llm_cfg, llm_p, max_slots=3, s_max=S_MAX,
                      cache_impl="paged", block_size=4, pool_blocks=16,
                      swap=True)
    sched = VerificationAwareScheduler(eng, chunk=8,
                                       preempt_policy="slo-aware")
    P = _prompts([12, 12, 12], seed=17)
    sched.submit_prefill(PrefillRequest(1, np.asarray(P[0])))
    sched.submit_prefill(PrefillRequest(
        2, np.asarray(P[1]), slo=StreamSLO(deadline_ms=1.0)))
    sched.submit_prefill(PrefillRequest(3, np.asarray(P[2])))
    evs = sched.run_iteration()
    slots = {e.req_id: e.slot for e in evs}
    # req 2's stream is deadline-bound; a no-SLO stream (infinite
    # slack) must be chosen instead
    assert slots[2] != slots[3]
    victim = sched._pick_victim()
    assert victim == slots[3]
    assert victim != slots[2]
    for s in slots.values():
        sched.release_slot(s)
    _drained(eng)


# ---------------------------------------------------------------------------
# Property: identity across dispositions and victim policies
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(4, 20), min_size=2, max_size=4),
       st.integers(0, len(PREEMPT_POLICIES) - 1),
       st.integers(0, 1))            # arrivals: together | staggered
@settings(max_examples=5, deadline=None)
def test_streams_identical_across_dispositions(dev, eng_dense, eng_recompute,
                                               eng_swap, lens, pol_i, arr_i):
    """Greedy token streams are byte-identical across {no-preemption
    (dense), recompute-eviction, swap-eviction} and across victim
    policies, whatever the prompt lengths and arrival pattern."""
    policy = PREEMPT_POLICIES[pol_i]
    prompts = _prompts(lens, seed=sum(lens) + 13 * len(lens))
    arrivals = None if arr_i == 0 else [i * 350.0 for i
                                        in range(len(prompts))]
    r_ref = SY.run_synera(dev, eng_dense, prompts, 10, concurrency=1)
    r_re = SY.run_synera(dev, eng_recompute, prompts, 10,
                         concurrency=len(prompts), arrivals=arrivals,
                         preempt_policy=policy)
    r_sw = SY.run_synera(dev, eng_swap, prompts, 10,
                         concurrency=len(prompts), arrivals=arrivals,
                         preempt_policy=policy)
    assert r_re.outputs == r_ref.outputs
    assert r_sw.outputs == r_ref.outputs
    _drained(eng_swap)
    _drained(eng_recompute)
