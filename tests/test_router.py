"""Multi-replica fleet routing tests (serving/router.py).

The invariant under test everywhere: *placement never changes content*.
Greedy token streams are deterministic functions of tokens and
positions only, so whatever the router does — round-robin, load
balancing, prefix-affinity, replica death with failover, saturation
degrade — every stream must be byte-identical to the single-engine
paged oracle.

Engines are module-scoped where tests only read token streams (a
released slot is fully reset, so reuse is safe and avoids jit
recompiles); tests that assert absolute pool counters (prefix-affinity
effectiveness) or permanently poison an engine (replica kill) build
fresh ones.
"""
import numpy as np
import pytest

import jax
from hypothesis import given, settings, strategies as st

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving import synergy as SY
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.router import ROUTE_POLICIES, ReplicaRouter
from repro.serving.server import WAIT_CLOUD, build_fleet


@pytest.fixture(scope="module")
def pair():
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    return slm_cfg, slm_p, llm_cfg, llm_p


@pytest.fixture(scope="module")
def dev(pair):
    slm_cfg, slm_p, _, _ = pair
    return DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False, use_pi=False)


def _mk_engine(pair, **kw):
    _, _, llm_cfg, llm_p = pair
    kw.setdefault("cache_impl", "paged")
    kw.setdefault("block_size", 16)
    kw.setdefault("share_prefix", True)
    return CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256, **kw)


@pytest.fixture(scope="module")
def fleet4(pair):
    """Four reusable paged prefix-sharing replicas (no retention, so a
    drained replica returns to pristine pool state between tests)."""
    return [_mk_engine(pair) for _ in range(4)]


def _prompts(n, length=8, shared=0, seed=5):
    rng = np.random.default_rng(seed)
    common = [int(t) for t in rng.integers(1, 60, 16)]
    out = []
    for i in range(n):
        suffix = [int(t) for t in rng.integers(1, 60, length)]
        out.append((common if i < shared else []) + suffix)
    return out


def _tokens(metrics):
    return [[int(t) for t in m.tokens] for m in metrics]


def _assert_pristine(eng):
    pool = eng.pool_stats
    assert pool["used_blocks"] == 0, pool
    assert (pool["free_blocks"] + pool["cached_free_blocks"]
            == pool["n_blocks"]), pool


# ---------------------------------------------------------------------------
# Identity property: policies x replica counts x arrivals x prefix overlap
# ---------------------------------------------------------------------------

_ORACLE_CACHE: dict = {}


def _oracle(dev, eng, prompts, max_new):
    key = (tuple(tuple(p) for p in prompts), max_new)
    if key not in _ORACLE_CACHE:
        r = SY.run_synera(dev, eng, prompts, max_new, concurrency=1)
        _ORACLE_CACHE[key] = [[int(t) for t in o] for o in r.outputs]
    return _ORACLE_CACHE[key]


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2), st.integers(0, 2),
       st.integers(0, 3), st.integers(0, 2))
def test_routing_identity_property(dev, fleet4, pair,
                                   policy_i, rep_i, arr_seed, frac_i):
    """Every stream's tokens are byte-identical to the single-engine
    oracle across policy x replica count x arrival pattern x
    shared-prefix fraction."""
    policy = ROUTE_POLICIES[policy_i]
    n_rep = (1, 2, 4)[rep_i]
    n, max_new = 4, 8
    prompts = _prompts(n, shared=(0, n // 2, n)[frac_i])
    if arr_seed == 0:
        arrivals = None
    elif arr_seed == 1:
        arrivals = [0.0] * n
    else:
        rng = np.random.default_rng(arr_seed)
        arrivals = np.cumsum(rng.exponential(40.0, n)).tolist()
    want = _oracle(dev, fleet4[0], prompts, max_new)

    r = SY.run_synera_fleet(dev, fleet4[:n_rep], prompts, max_new,
                            policy=policy, concurrency=n,
                            arrivals=arrivals)
    got = [[int(t) for t in o] for o in r.outputs]
    assert got == want, (policy, n_rep, arr_seed, frac_i)
    stats = r.extras["scheduler"]
    assert stats["replicas"] == n_rep
    assert stats["route_policy"] == policy
    assert stats["completed_streams"] == n
    assert stats["degraded_streams"] == 0
    assert len(r.extras["replicas"]) == n_rep
    for i, d in enumerate(r.extras["replicas"]):
        assert d["replica"] == i and not d["dead"]
    for eng in fleet4[:n_rep]:
        _assert_pristine(eng)


def test_round_robin_rotates(dev, fleet4):
    """The identity oracle policy really is state-oblivious rotation."""
    prompts = _prompts(4)
    router = ReplicaRouter(build_fleet(dev, fleet4[:2]),
                           policy="round-robin")
    router.serve(prompts, 4, concurrency=4)
    owners = [router.owner[id(s)] for s in router.sessions]
    assert owners == [0, 1, 0, 1]


# ---------------------------------------------------------------------------
# Fault injection: replica death mid-verify
# ---------------------------------------------------------------------------

def test_replica_kill_mid_verify(dev, fleet4, pair):
    """Kill replica 0 while it has a verify in flight: its sessions are
    re-placed on the survivor as from-scratch prefills, finish with
    byte-identical outputs, and the survivor leaks no blocks.  The dead
    engine is poisoned — any further dispatch raises."""
    n, max_new = 4, 16
    prompts = _prompts(n, length=12, seed=11)
    want = _oracle(dev, fleet4[0], prompts, max_new)

    engines = [_mk_engine(pair), _mk_engine(pair)]
    router = ReplicaRouter(build_fleet(dev, engines), policy="round-robin")
    sess = [router.open_session(p, max_new) for p in prompts]
    for _ in range(400):
        router.step()
        if any(s.state == WAIT_CLOUD
               for s in router.replicas[0].sessions if not s.done):
            break
    else:
        pytest.fail("replica 0 never reached a mid-verify state")

    moved = router.kill_replica(0)
    assert moved >= 1
    assert router.kill_replica(0) == 0          # idempotent
    while router.step():
        pass

    assert _tokens([s.metrics for s in sess]) == want
    assert engines[0].dead
    with pytest.raises(RuntimeError, match="marked dead"):
        engines[0].feed(np.zeros((2, 4), np.int32),
                        np.full((2, 4), -1, np.int32))
    _assert_pristine(engines[1])                # survivor leaks nothing
    stats = router.stats()
    assert stats["rerouted_sessions"] == moved
    assert stats["dead_replicas"] == 1
    assert stats["completed_streams"] == n
    assert router.replica_stats(0)["dead"]
    assert not router.replica_stats(1)["dead"]


def test_kill_before_first_step_reroutes_fresh_sessions(dev, pair):
    """Sessions that never reached the cloud (still fresh) survive a
    replica death too: they re-run as fresh sessions on the survivor."""
    n, max_new = 2, 8
    prompts = _prompts(n, seed=13)
    engines = [_mk_engine(pair), _mk_engine(pair)]
    router = ReplicaRouter(build_fleet(dev, engines), policy="round-robin")
    sess = [router.open_session(p, max_new) for p in prompts]
    moved = router.kill_replica(0)              # before any step()
    assert moved == 1                           # session 0 was on replica 0
    while router.step():
        pass
    assert all(s.done and s.metrics is not None for s in sess)
    _assert_pristine(engines[1])
    ref = SY.run_synera(dev, engines[1], prompts, max_new, concurrency=1)
    assert _tokens([s.metrics for s in sess]) == \
        [[int(t) for t in o] for o in ref.outputs]


# ---------------------------------------------------------------------------
# Fault injection: fleet saturation -> degrade to device-only
# ---------------------------------------------------------------------------

def test_saturation_degrades_to_device(dev, fleet4):
    """With every replica past its queue cap the router does not 429:
    the stream completes device-only (SLM solo, zero cloud tokens) and
    ``degraded_streams`` increments."""
    prompts = _prompts(3, seed=17)
    max_new = 8
    router = ReplicaRouter(build_fleet(dev, fleet4[:1]),
                           policy="least-loaded", replica_queue_cap=2)
    s1 = router.open_session(prompts[0], max_new)
    s2 = router.open_session(prompts[1], max_new)
    s3 = router.open_session(prompts[2], max_new)   # fleet saturated
    # the degraded stream completed synchronously, solo on the device
    assert s3.done and s3.metrics is not None
    assert len(s3.metrics.tokens) == max_new
    assert s3.metrics.n_cloud_tokens == 0
    assert s3.metrics.n_cloud_fed_tokens == 0
    assert router.degraded_streams == 1
    assert router.owner[id(s3)] == -1
    while router.step():
        pass
    assert s1.done and s2.done
    stats = router.stats()
    assert stats["degraded_streams"] == 1
    assert stats["completed_streams"] == 3          # degraded one included
    # capacity freed: the next open goes back to the replica
    s4 = router.open_session(prompts[0], max_new)
    assert router.owner[id(s4)] == 0
    while router.step():
        pass
    # same prompt, both cloud-verified: determinism unaffected by the
    # degrade episode in between
    assert _tokens([s4.metrics]) == _tokens([s1.metrics])


# ---------------------------------------------------------------------------
# Prefix-affinity x persistent prefix cache (PR 8) composition
# ---------------------------------------------------------------------------

def test_prefix_affinity_lands_on_cached_replica(dev, pair):
    """Two waves sharing a 32-token system prompt: prefix-affinity
    concentrates every stream on the replica that already holds the
    prefix (wave 2 revives/dedupes retained blocks); least-loaded on a
    cold fleet spreads the same wave and reuses nothing."""
    rng = np.random.default_rng(23)
    common = [int(t) for t in rng.integers(1, 60, 32)]
    wave1 = [common + [int(t) for t in rng.integers(1, 60, 8)]
             for _ in range(2)]
    wave2 = [common + [int(t) for t in rng.integers(1, 60, 8)]
             for _ in range(2)]
    max_new = 8

    engines = [_mk_engine(pair, retain_prefix=True) for _ in range(2)]
    router = ReplicaRouter(build_fleet(dev, engines),
                           policy="prefix-affinity")
    m1 = router.serve(wave1, max_new, concurrency=1)
    owners1 = [router.owner[id(s)] for s in router.sessions]
    fed_w1 = router.stats()["prefill_fed_tokens"]
    reuse_w1 = (router.stats()["revived_blocks"]
                + router.stats()["dedupe_hit_blocks"])
    m2 = router.serve(wave2, max_new, concurrency=2)
    owners2 = [router.owner[id(s)] for s in router.sessions[len(wave1):]]
    stats = router.stats()

    # wave 1 stream 2 and all of wave 2 land where the prefix lives
    assert set(owners1) == {0} and set(owners2) == {0}
    assert stats["affinity_hits"] >= 3          # every probe after the first
    # wave 2 adopted retained blocks instead of re-prefilling the prefix
    reuse_w2 = (stats["revived_blocks"] + stats["dedupe_hit_blocks"])
    assert reuse_w2 > reuse_w1
    assert stats["revived_blocks"] > 0
    # and fed strictly fewer prefill tokens than a cold wave would
    assert (stats["prefill_fed_tokens"] - fed_w1
            < sum(len(p) for p in wave2))

    # identity: same waves on a single engine, sequentially
    assert _tokens(m1) == _oracle(dev, engines[0], wave1, max_new)
    assert _tokens(m2) == _oracle(dev, engines[0], wave2, max_new)

    # control: a COLD least-loaded fleet spreads the wave; nothing to
    # revive, nothing to dedupe across replicas
    cold = [_mk_engine(pair, retain_prefix=True) for _ in range(2)]
    router_ll = ReplicaRouter(build_fleet(dev, cold), policy="least-loaded")
    m2c = router_ll.serve(wave2, max_new, concurrency=1)
    st = router_ll.stats()
    assert st["revived_blocks"] + st["dedupe_hit_blocks"] == 0
    assert st["affinity_hits"] == 0
    owners_ll = [router_ll.owner[id(s)] for s in router_ll.sessions]
    assert set(owners_ll) == {0, 1}             # spread, not concentrated
    assert _tokens(m2c) == _oracle(dev, engines[0], wave2, max_new)
