"""API load benchmark: Poisson arrivals over real sockets against the
OpenAI-compatible gateway (serving/gateway/), measuring what a client
actually observes — wall-clock TTFT, TPOT and E2E percentiles, goodput,
and 429 behaviour at the queue cap — plus the modeled-vs-real
cross-check the gateway's RealClock makes possible on the same run.

Each request is one blocking socket on its own thread (the container
has no HTTP client library): it sleeps until its Poisson arrival time,
POSTs a streaming chat completion, timestamps every SSE chunk, and
parses the streamed token ids back out.  Accepted streams are asserted
**byte-identical** to an in-process ``run_synera`` over the same
prompts (the gateway adds transport, not tokens); the summary records
the same ``outputs_sha`` digest serve.py prints.

Cross-check: the server serves at host speed while ``RealClock``
accumulates the modeled schedule as shadow time, so the summary reports
``wall_ms`` next to ``modeled_ms``.  Under ``--pace`` the engine sleeps
through modeled costs, making wall >= modeled with the excess being
host compute + transport (asserted in ``--check``; see
docs/serving_api.md for the tolerance discussion).

Usage:
  PYTHONPATH=src:. python -m benchmarks.api_bench \
      [--requests 24] [--rate 8] [--max-new 16] \
      [--max-active 4] [--queue-cap 8] [--pace] \
      [--out benchmarks/BENCH_api.json]
  PYTHONPATH=src:. python -m benchmarks.api_bench --check   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import socket
import threading
import time

import numpy as np


# ---------------------------------------------------------------------
# minimal blocking HTTP/SSE client
# ---------------------------------------------------------------------

def _post_stream(port: int, prompt, max_new: int, timeout: float = 600.0):
    """POST one streaming chat completion; returns a per-request record
    with client-side wall timings (seconds, monotonic) per SSE chunk."""
    body = json.dumps({
        "model": "bench", "stream": True, "max_tokens": max_new,
        "messages": [{"role": "user",
                      "content": " ".join(str(t) for t in prompt)}],
    }).encode()
    head = (f"POST /v1/chat/completions HTTP/1.1\r\nHost: bench\r\n"
            f"Connection: close\r\nContent-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n\r\n").encode()
    rec = dict(status=0, tokens=[], t_send=time.monotonic(),
               t_first=None, t_last=None, t_done=None, retry_after=None)
    sock = socket.create_connection(("127.0.0.1", port), timeout=timeout)
    try:
        sock.sendall(head + body)
        data = b""
        while True:
            chunk = sock.recv(65536)
            if not chunk:
                break
            data += chunk
            if rec["t_first"] is None and b'"content"' in data:
                rec["t_first"] = time.monotonic()
        rec["t_done"] = time.monotonic()
    finally:
        sock.close()
    headtxt, _, payload = data.partition(b"\r\n\r\n")
    rec["status"] = int(headtxt.split(None, 2)[1])
    for ln in headtxt.decode("latin1").split("\r\n"):
        if ln.lower().startswith("retry-after:"):
            rec["retry_after"] = ln.split(":", 1)[1].strip()
    if rec["status"] != 200:
        return rec
    for frame in payload.split(b"\n\n"):
        if not frame.startswith(b"data: ") or frame == b"data: [DONE]":
            continue
        delta = json.loads(frame[6:])["choices"][0]["delta"]
        if "content" in delta:
            rec["tokens"] += [int(t) for t in delta["content"].split()]
            rec["t_last"] = rec["t_done"]
    return rec


def _get_json(port: int, path: str) -> dict:
    sock = socket.create_connection(("127.0.0.1", port), timeout=60)
    try:
        sock.sendall(f"GET {path} HTTP/1.1\r\nHost: b\r\n"
                     f"Connection: close\r\n\r\n".encode())
        data = b""
        while True:
            chunk = sock.recv(65536)
            if not chunk:
                break
            data += chunk
    finally:
        sock.close()
    return json.loads(data.partition(b"\r\n\r\n")[2])


def _pcts(xs):
    if not xs:
        return {}
    return {f"p{q}": float(np.percentile(xs, q)) for q in (50, 90, 95, 99)}


# ---------------------------------------------------------------------
# the bench
# ---------------------------------------------------------------------

def run_bench(requests: int = 24, rate: float = 8.0, max_new: int = 16,
              max_active: int = 4, queue_cap: int = 8, pace: bool = False,
              seed: int = 0, burst: bool = False) -> dict:
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY
    from repro.serving.gateway import Gateway, GatewayConfig
    from repro.serving.link import RealClock
    from repro.serving.server import SyneraServer

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p, policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    eng = PC.make_engine(llm_cfg, llm_p, slots=max_active)
    prompts = [p for p, _ in PC.eval_set(task, requests, seed=seed + 7)]

    # in-process reference: the gateway must stream these exact tokens
    ref = SY.run_synera(dev, eng, prompts, max_new, concurrency=1)
    import hashlib
    ref_sha = hashlib.sha256(json.dumps(
        [[int(t) for t in o] for o in ref.outputs]).encode()).hexdigest()[:16]

    server = SyneraServer(dev, eng, clock=RealClock(pace=pace),
                          clamp_arrivals=not pace)
    gw = Gateway(server, GatewayConfig(
        port=0, max_active=max_active, queue_cap=queue_cap,
        max_new_default=max_new, max_new_cap=max(max_new, 256)))
    gw.start()

    rng = np.random.default_rng(seed + 13)
    gaps = (np.zeros(requests) if burst
            else rng.exponential(1.0 / rate, requests))
    arrivals = np.cumsum(gaps)
    records: list = [None] * requests

    def _one(i):
        time.sleep(max(0.0, arrivals[i] - (time.monotonic() - t0)))
        records[i] = _post_stream(gw.port, prompts[i], max_new)

    try:
        t0 = time.monotonic()
        threads = [threading.Thread(target=_one, args=(i,))
                   for i in range(requests)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.monotonic() - t0
        metrics = _get_json(gw.port, "/metrics?format=json")
    finally:
        gw.close()

    ok = [r for r in records if r["status"] == 200]
    rejected = [r for r in records if r["status"] == 429]
    mismatches = sum(1 for i, r in enumerate(records)
                     if r["status"] == 200
                     and r["tokens"] != [int(t) for t in ref.outputs[i]])
    ttft = [(r["t_first"] - r["t_send"]) * 1e3 for r in ok if r["t_first"]]
    e2e = [(r["t_done"] - r["t_send"]) * 1e3 for r in ok]
    tpot = [(r["t_last"] - r["t_first"]) / (len(r["tokens"]) - 1) * 1e3
            for r in ok if r["t_last"] and len(r["tokens"]) > 1]
    n_tokens = sum(len(r["tokens"]) for r in ok)

    return dict(
        config=dict(requests=requests, rate_rps=None if burst else rate,
                    burst=burst, max_new=max_new, max_active=max_active,
                    queue_cap=queue_cap, pace=pace, seed=seed),
        wall_s=wall_s,
        accepted=len(ok),
        rejected_429=len(rejected),
        retry_after_present=all(r["retry_after"] is not None
                                for r in rejected),
        goodput_rps=len(ok) / wall_s,
        goodput_tok_s=n_tokens / wall_s,
        ttft_ms=dict(mean=float(np.mean(ttft)) if ttft else 0.0,
                     **_pcts(ttft)),
        tpot_ms=dict(mean=float(np.mean(tpot)) if tpot else 0.0,
                     **_pcts(tpot)),
        e2e_ms=dict(mean=float(np.mean(e2e)) if e2e else 0.0,
                    **_pcts(e2e)),
        identity=dict(outputs_sha=ref_sha, mismatched_streams=mismatches),
        # modeled-vs-real cross-check: both clocks from the same run
        cross_check=dict(
            wall_ms=wall_s * 1e3,
            modeled_ms=metrics["modeled_ms"],
            wall_over_modeled=wall_s * 1e3 / max(metrics["modeled_ms"], 1e-9),
            server_ttft_modeled_p50=metrics["ttft_ms_p50"],
            server_e2e_modeled_p50=metrics["e2e_ms_p50"]),
        server=dict(completed_streams=metrics["completed_streams"],
                    cancelled_streams=metrics["cancelled_streams"],
                    rejected_requests=metrics["rejected_requests"],
                    iterations=metrics["iterations"],
                    mean_verify_occupancy=metrics["mean_verify_occupancy"]),
    )


def check(res: dict) -> None:
    """CI assertions over a saturating burst run (see ci.yml)."""
    assert res["accepted"] >= 1, res
    assert res["identity"]["mismatched_streams"] == 0, \
        "streamed tokens diverged from the in-process reference"
    assert res["rejected_429"] >= 1, \
        f"queue cap never tripped: {res['rejected_429']} rejections"
    assert res["retry_after_present"], "429 without Retry-After"
    assert res["server"]["rejected_requests"] == res["rejected_429"], res
    assert res["cross_check"]["modeled_ms"] > 0, res
    if res["config"]["pace"]:
        # paced: the engine sleeps through modeled costs, so wall time
        # must dominate the modeled schedule
        assert res["cross_check"]["wall_over_modeled"] >= 1.0, res
    assert res["ttft_ms"]["p50"] > 0 and res["e2e_ms"]["p95"] > 0, res
    print("api_bench --check: all assertions passed")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/s of wall time")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-active", type=int, default=4)
    ap.add_argument("--queue-cap", type=int, default=8)
    ap.add_argument("--pace", action="store_true",
                    help="pace the engine to the modeled schedule "
                         "(wall latencies track modeled ones)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--burst", action="store_true",
                    help="all requests arrive at t=0 (saturation test)")
    ap.add_argument("--check", action="store_true",
                    help="CI smoke: small saturating burst, assert 429 "
                         "at the cap + streamed-token identity")
    ap.add_argument("--out", default="benchmarks/BENCH_api.json")
    args = ap.parse_args()

    if args.check:
        # one lone streaming request first: must be accepted, never
        # rejected, and byte-identical to the in-process reference
        solo = run_bench(requests=1, max_new=8, max_active=2, queue_cap=1,
                         pace=args.pace, seed=args.seed, burst=True)
        assert solo["accepted"] == 1 and solo["rejected_429"] == 0, solo
        assert solo["identity"]["mismatched_streams"] == 0, solo
        print("api_bench --check: solo stream ok")
        res = run_bench(requests=8, max_new=8, max_active=2, queue_cap=1,
                        pace=args.pace, seed=args.seed, burst=True)
        res["solo"] = solo
    else:
        res = run_bench(requests=args.requests, rate=args.rate,
                        max_new=args.max_new, max_active=args.max_active,
                        queue_cap=args.queue_cap, pace=args.pace,
                        seed=args.seed, burst=args.burst)
        if not args.pace:
            # compact paced companion: the engine sleeps through modeled
            # costs, so wall >= modeled must hold (the strict direction
            # of the cross-check; unpaced only yields the ratio)
            paced = run_bench(requests=6, rate=args.rate, max_new=8,
                              max_active=args.max_active,
                              queue_cap=args.queue_cap, pace=True,
                              seed=args.seed)
            assert paced["cross_check"]["wall_over_modeled"] >= 1.0, paced
            res["paced"] = paced
    print(json.dumps(res, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.out}")
    if args.check:
        check(res)


if __name__ == "__main__":
    main()
