"""Kernel microbenchmarks.

CAVEAT: this container executes Pallas in interpret mode on CPU, so
``us_per_call`` is structural-validation timing, NOT TPU performance.
TPU performance is analyzed from the compiled dry-run (§Roofline); the
numbers here certify correctness (max_err vs oracle) and give relative
interpreter cost.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=3):
    out = fn(*args)  # warmup/compile
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / n * 1e6


def kernel_micro():
    from repro.kernels.attn_importance.attn_importance import (
        attn_with_importance)
    from repro.kernels.attn_importance.ref import attn_with_importance_ref
    from repro.kernels.decode_gqa.decode_gqa import decode_attention
    from repro.kernels.decode_gqa.ref import decode_attention_ref
    from repro.kernels.partial_prefill.partial_prefill import (
        partial_prefill_attention)
    from repro.kernels.partial_prefill.ref import partial_prefill_ref
    from repro.kernels.ssd_scan.ssd_scan import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    rows = []
    key = jax.random.PRNGKey(0)

    # attn + importance: SLM-scale
    B, T, nh, nkv, hd = 1, 256, 8, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd))
    k = jax.random.normal(ks[1], (B, T, nkv, hd))
    v = jax.random.normal(ks[2], (B, T, nkv, hd))
    f = jax.jit(lambda q, k, v: attn_with_importance(q, k, v))
    us = _time(f, q, k, v)
    o2, i2 = attn_with_importance_ref(q, k, v)
    o1, i1 = f(q, k, v)
    err = max(float(jnp.abs(o1 - o2).max()), float(jnp.abs(i1 - i2).max()))
    rows.append(dict(name="attn_importance", us_per_call=us, max_err=err,
                     shape=f"B{B}xT{T}xh{nh}/{nkv}xd{hd}"))

    # partial prefill: chunk 32 over 1k cache
    B, C, S = 2, 32, 1024
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, C, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nkv, hd))
    v = jax.random.normal(ks[2], (B, S, nkv, hd))
    kp = np.full((B, S), -1, np.int32)
    kp[:, :500] = np.arange(500)
    qp = np.tile(500 + np.arange(C), (B, 1)).astype(np.int32)
    kp[:, 500:500 + C] = qp
    qp, kp = jnp.asarray(qp), jnp.asarray(kp)
    f = jax.jit(lambda *a: partial_prefill_attention(*a, block_kv=256))
    us = _time(f, q, k, v, qp, kp)
    o1 = f(q, k, v, qp, kp)
    o2 = partial_prefill_ref(q, k, v, qp, kp)
    rows.append(dict(name="partial_prefill", us_per_call=us,
                     max_err=float(jnp.abs(o1 - o2).max()),
                     shape=f"B{B}xC{C}xS{S}"))

    # decode GQA
    q1 = jax.random.normal(ks[0], (B, nh, hd))
    qpos = jnp.full((B,), 520, jnp.int32)
    f = jax.jit(lambda *a: decode_attention(*a, block_kv=256))
    us = _time(f, q1, k, v, qpos, kp)
    o1 = f(q1, k, v, qpos, kp)
    o2 = decode_attention_ref(q1, k, v, qpos, kp)
    rows.append(dict(name="decode_gqa", us_per_call=us,
                     max_err=float(jnp.abs(o1 - o2).max()),
                     shape=f"B{B}xS{S}xh{nh}/{nkv}"))

    # SSD scan
    B, L, H, P, N = 1, 256, 4, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    f = jax.jit(lambda *a: ssd_scan(*a, chunk=64))
    us = _time(f, x, dt, A, Bm, Cm)
    y1, h1 = f(x, dt, A, Bm, Cm)
    y2, h2 = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=64)
    rows.append(dict(name="ssd_scan", us_per_call=us,
                     max_err=float(jnp.abs(y1 - y2).max()),
                     shape=f"B{B}xL{L}xH{H}xP{P}xN{N}"))
    return rows


# ---------------------------------------------------------------------------
# Paged-vs-dense sweep (PR 7: fused DMA + flash-decode split-KV)
# ---------------------------------------------------------------------------

def _build_paged(rng, B, bs, S, nkv, hd, tail=7):
    """Random paged pool + block tables + the gathered dense view.

    Streams hold ``S - tail`` tokens so the last block is ragged; block
    ids are a random permutation of the pool (non-contiguous, like a
    live allocator), and ``pad`` extra pool blocks stay unmapped.
    """
    mbps = S // bs
    nb = mbps + 4
    kp = rng.standard_normal((nb, bs, nkv, hd)).astype(np.float32)
    vp = rng.standard_normal((nb, bs, nkv, hd)).astype(np.float32)
    pos = np.full((nb, bs), -1, np.int32)
    L = S - tail
    bt = np.full((B, mbps), -1, np.int32)
    kd = np.zeros((B, S, nkv, hd), np.float32)
    vd = np.zeros((B, S, nkv, hd), np.float32)
    kpos = np.full((B, S), -1, np.int32)
    for b in range(B):
        perm = rng.permutation(nb)[: -(-L // bs)]
        for j, blk in enumerate(perm):
            base = j * bs
            n = min(bs, L - base)
            pos[blk, :n] = np.arange(base, base + n)
            bt[b, j] = blk
            kd[b, base:base + n] = kp[blk, :n]
            vd[b, base:base + n] = vp[blk, :n]
            kpos[b, base:base + n] = np.arange(base, base + n)
    J = jnp.asarray
    return dict(k_pool=J(kp), v_pool=J(vp), pos_pool=J(pos), bt=J(bt),
                kd=J(kd), vd=J(vd), kpos=J(kpos), L=L, mbps=mbps)


def paged_micro(full: bool = True, n: int = 1):
    """Paged-vs-dense rows: correctness (max_err vs the dense kernel on
    the gathered view), interpreter cost ratio, and the grid-step
    accounting the fused-DMA pass exists for (``step_reduction`` =
    unfused KV-axis steps / fused steps; >= 4x at block_kv=128/bs=16).
    """
    from repro.kernels import paged as PG
    from repro.kernels.decode_gqa.decode_gqa import (
        decode_attention, decode_attention_paged)
    from repro.kernels.partial_prefill.partial_prefill import (
        partial_prefill_attention, partial_prefill_attention_paged)

    rows = []
    B, nh, nkv, hd, C = 1, 4, 2, 64, 32
    rng = np.random.default_rng(11)
    sizes = [(bs, S) for bs in (16, 32)
             for S in ((512, 2048, 8192) if full else (512,))]
    for bs, S in sizes:
        # tail = bs + 7: ragged last block AND an unmapped trailing
        # table entry, so every row exercises both mask paths
        d = _build_paged(rng, B, bs, S, nkv, hd, tail=bs + 7)
        L, mbps = d["L"], d["mbps"]
        # decode: one query at the stream head
        q1 = jnp.asarray(rng.standard_normal((B, nh, hd)), jnp.float32)
        qpos1 = jnp.full((B,), L - 1, jnp.int32)
        # partial prefill: a verify chunk of C tokens ending at the head
        qC = jnp.asarray(rng.standard_normal((B, C, nh, hd)), jnp.float32)
        qposC = jnp.tile(jnp.arange(L - C, L, dtype=jnp.int32), (B, 1))
        fdec = jax.jit(lambda *a: decode_attention(*a, block_kv=256))
        fpp = jax.jit(lambda *a: partial_prefill_attention(*a, block_kv=256))
        dense = {
            "decode": (_time(fdec, q1, d["kd"], d["vd"], qpos1, d["kpos"],
                             n=n),
                       fdec(q1, d["kd"], d["vd"], qpos1, d["kpos"])),
            "partial_prefill": (_time(fpp, qC, d["kd"], d["vd"], qposC,
                                      d["kpos"], n=n),
                                fpp(qC, d["kd"], d["vd"], qposC,
                                    d["kpos"])),
        }
        for blk, sp in ((bs, 1), (128, 1), (128, 4)):
            gi = PG.paged_grid_info(mbps, bs, blk, sp)
            for kind, paged_fn, qa, qp in (
                ("decode", decode_attention_paged, q1, qpos1),
                ("partial_prefill", partial_prefill_attention_paged, qC,
                 qposC),
            ):
                f = jax.jit(lambda *a, _f=paged_fn, _b=blk, _s=sp: _f(
                    *a, block_kv=_b, kv_splits=_s))
                args = (qa, d["k_pool"], d["v_pool"], qp, d["pos_pool"],
                        d["bt"])
                us = _time(f, *args, n=n)
                dus, oref = dense[kind]
                err = float(jnp.abs(f(*args) - oref).max())
                rows.append(dict(
                    name=f"paged_{kind}", block_size=bs, S=S,
                    block_kv=blk, kv_splits=sp, fuse=gi["fuse"],
                    kv_steps=gi["kv_steps_total"],
                    kv_steps_unfused=gi["kv_steps_unfused"],
                    step_reduction=gi["kv_steps_unfused"]
                    / gi["kv_steps_total"],
                    tokens_per_step=gi["tokens_per_step"],
                    us_per_call=us, dense_us_per_call=dus,
                    paged_to_dense_ratio=us / dus, max_err=err,
                    shape=f"B{B}xS{S}xh{nh}/{nkv}xd{hd}"))
    return rows


def paged_e2e_rows(max_new: int = 24, n_prompts: int = 3):
    """Greedy token-stream identity, end to end: a paged+pallas engine
    must emit byte-identical streams to the dense+pallas engine across
    fuse/split settings (the serving-level restatement of max_err=0)."""
    from repro.configs.synera_pair import tiny_pair
    from repro.core.offload import OffloadPolicy
    from repro.models import model as M
    from repro.serving import synergy as SY
    from repro.serving.device import DeviceRuntime
    from repro.serving.engine import CloudEngine

    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    llm_cfg = llm_cfg.replace(attn_impl="pallas")
    slm_p = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_p = M.init_params(llm_cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(5)
    prompts = [[int(t) for t in rng.integers(1, 60, size=12)]
               for _ in range(n_prompts)]

    def streams(eng):
        dev = DeviceRuntime(slm_cfg, slm_p, s_max=256, gamma=4, seed=0,
                            policy=OffloadPolicy(mode="all"),
                            use_early_exit=False, use_pi=False)
        r = SY.run_synera(dev, eng, prompts, max_new)
        return [[int(t) for t in o] for o in r.outputs]

    ref = streams(CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256))
    rows = []
    for blk, sp in ((16, 1), (128, 1), (128, 4)):
        out = streams(CloudEngine(llm_cfg, llm_p, max_slots=2, s_max=256,
                                  cache_impl="paged", block_size=16,
                                  paged_block_kv=blk, kv_splits=sp))
        mism = sum(
            len(a) != len(b) or any(x != y for x, y in zip(a, b))
            for a, b in zip(ref, out))
        rows.append(dict(name="paged_e2e_stream", block_size=16,
                         block_kv=blk, kv_splits=sp, n_streams=len(ref),
                         max_new=max_new, token_mismatches=mism))
    return rows


def paged_main(full: bool = True):
    """Paged-kernel bench: prints the sweep, asserts correctness + the
    fusion win, and (full mode) writes BENCH_paged_kernels.json."""
    import json
    import pathlib
    rows = paged_micro(full=full)
    e2e = paged_e2e_rows() if full else []
    print(json.dumps(rows + e2e, indent=2))
    bad = [r for r in rows if not r["max_err"] < 5e-5]
    if bad:
        raise SystemExit(f"paged kernel error vs dense oracle: {bad}")
    weak = [r for r in rows
            if r["block_kv"] == 128 and r["block_size"] == 16
            and r["step_reduction"] < 4]
    if weak:
        raise SystemExit(f"fused-DMA step reduction below 4x: {weak}")
    bad_e2e = [r for r in e2e if r["token_mismatches"] != 0]
    if bad_e2e:
        raise SystemExit(f"paged e2e streams diverged from dense: "
                         f"{bad_e2e}")
    if full:
        out = pathlib.Path(__file__).parent / "BENCH_paged_kernels.json"
        out.write_text(json.dumps(rows + e2e, indent=2) + "\n")
        print(f"wrote {out}")
    print(f"{len(rows)} paged rows OK"
          + (f", {len(e2e)} e2e rows OK" if e2e else ""))


def main():
    """CI smoke: every kernel must run (interpret mode) and match its
    oracle — a cheap early-warning for Pallas dispatch regressions."""
    import json
    rows = kernel_micro()
    print(json.dumps(rows, indent=2))
    bad = [r for r in rows if not r["max_err"] < 5e-2]
    if bad:
        raise SystemExit(f"kernel error vs oracle too large: {bad}")
    print(f"{len(rows)} kernels OK")


if __name__ == "__main__":
    import sys
    if "--paged" in sys.argv:
        paged_main(full=True)
    elif "--paged-smoke" in sys.argv:
        paged_main(full=False)
    else:
        main()
