"""Kernel microbenchmarks.

CAVEAT: this container executes Pallas in interpret mode on CPU, so
``us_per_call`` is structural-validation timing, NOT TPU performance.
TPU performance is analyzed from the compiled dry-run (§Roofline); the
numbers here certify correctness (max_err vs oracle) and give relative
interpreter cost.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=3):
    out = fn(*args)  # warmup/compile
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / n * 1e6


def kernel_micro():
    from repro.kernels.attn_importance.attn_importance import (
        attn_with_importance)
    from repro.kernels.attn_importance.ref import attn_with_importance_ref
    from repro.kernels.decode_gqa.decode_gqa import decode_attention
    from repro.kernels.decode_gqa.ref import decode_attention_ref
    from repro.kernels.partial_prefill.partial_prefill import (
        partial_prefill_attention)
    from repro.kernels.partial_prefill.ref import partial_prefill_ref
    from repro.kernels.ssd_scan.ssd_scan import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    rows = []
    key = jax.random.PRNGKey(0)

    # attn + importance: SLM-scale
    B, T, nh, nkv, hd = 1, 256, 8, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd))
    k = jax.random.normal(ks[1], (B, T, nkv, hd))
    v = jax.random.normal(ks[2], (B, T, nkv, hd))
    f = jax.jit(lambda q, k, v: attn_with_importance(q, k, v))
    us = _time(f, q, k, v)
    o2, i2 = attn_with_importance_ref(q, k, v)
    o1, i1 = f(q, k, v)
    err = max(float(jnp.abs(o1 - o2).max()), float(jnp.abs(i1 - i2).max()))
    rows.append(dict(name="attn_importance", us_per_call=us, max_err=err,
                     shape=f"B{B}xT{T}xh{nh}/{nkv}xd{hd}"))

    # partial prefill: chunk 32 over 1k cache
    B, C, S = 2, 32, 1024
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, C, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nkv, hd))
    v = jax.random.normal(ks[2], (B, S, nkv, hd))
    kp = np.full((B, S), -1, np.int32)
    kp[:, :500] = np.arange(500)
    qp = np.tile(500 + np.arange(C), (B, 1)).astype(np.int32)
    kp[:, 500:500 + C] = qp
    qp, kp = jnp.asarray(qp), jnp.asarray(kp)
    f = jax.jit(lambda *a: partial_prefill_attention(*a, block_kv=256))
    us = _time(f, q, k, v, qp, kp)
    o1 = f(q, k, v, qp, kp)
    o2 = partial_prefill_ref(q, k, v, qp, kp)
    rows.append(dict(name="partial_prefill", us_per_call=us,
                     max_err=float(jnp.abs(o1 - o2).max()),
                     shape=f"B{B}xC{C}xS{S}"))

    # decode GQA
    q1 = jax.random.normal(ks[0], (B, nh, hd))
    qpos = jnp.full((B,), 520, jnp.int32)
    f = jax.jit(lambda *a: decode_attention(*a, block_kv=256))
    us = _time(f, q1, k, v, qpos, kp)
    o1 = f(q1, k, v, qpos, kp)
    o2 = decode_attention_ref(q1, k, v, qpos, kp)
    rows.append(dict(name="decode_gqa", us_per_call=us,
                     max_err=float(jnp.abs(o1 - o2).max()),
                     shape=f"B{B}xS{S}xh{nh}/{nkv}"))

    # SSD scan
    B, L, H, P, N = 1, 256, 4, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    f = jax.jit(lambda *a: ssd_scan(*a, chunk=64))
    us = _time(f, x, dt, A, Bm, Cm)
    y1, h1 = f(x, dt, A, Bm, Cm)
    y2, h2 = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=64)
    rows.append(dict(name="ssd_scan", us_per_call=us,
                     max_err=float(jnp.abs(y1 - y2).max()),
                     shape=f"B{B}xL{L}xH{H}xP{P}xN{N}"))
    return rows


def main():
    """CI smoke: every kernel must run (interpret mode) and match its
    oracle — a cheap early-warning for Pallas dispatch regressions."""
    import json
    rows = kernel_micro()
    print(json.dumps(rows, indent=2))
    bad = [r for r in rows if not r["max_err"] < 5e-2]
    if bad:
        raise SystemExit(f"kernel error vs oracle too large: {bad}")
    print(f"{len(rows)} kernels OK")


if __name__ == "__main__":
    main()
