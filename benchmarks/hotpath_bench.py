"""Cloud hot-path benchmark: fused on-device verification vs the PR-1
full-logits round trip (ROADMAP: make the hot path measurably faster).

At paper-scale vocabs the pre-change engine moved the full
(slots, chunk, V) float32 logits to the host EVERY verify iteration
(8 x 32 x 32768 x 4B = 32 MiB/iter at the default shape here; ~128 MiB
at Llama-3 128k vocab) and verified drafts in per-request host numpy.
The fused engine keeps the vocab axis device-resident: per row only an
argmax id, the gathered p(target) and a top-k support cross the
boundary — vocab-independent, ~72 B/row at K=8.

Both engines run the SAME synthetic verification workload (8 slots,
gamma=4 drafts, Sarathi chunk 32) through the real
VerificationAwareScheduler; greedy results are asserted byte-identical.
Wall time per verify iteration includes the host-side verifier work
(numpy argmax/stack for legacy, sparse-row decisions for fused), i.e.
the full scheduler iteration as served.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hotpath_bench [--fast] \
      [--vocab 32768] [--out benchmarks/BENCH_hotpath.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def build_engine(vocab: int, slots: int, verify_top_k: int = 8):
    import jax
    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.serving.engine import CloudEngine

    cfg = ModelConfig(
        name="hotpath-llm", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=vocab,
        rope_theta=10_000.0, remat=False, dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return CloudEngine(cfg, params, max_slots=slots, s_max=256,
                       verify_top_k=verify_top_k)


def _make_workload(slots: int, rounds: int, gamma: int, vocab: int,
                   seed: int):
    """Per (round, slot): (uncached, draft, q_sparse) arrays, fixed up
    front so every mode serves the identical request stream."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, vocab, size=16).astype(np.int64)
               for _ in range(slots)]
    work = []
    for _ in range(rounds):
        per_slot = []
        for _ in range(slots):
            unc = rng.integers(1, vocab,
                               size=int(rng.integers(0, 3))).astype(np.int64)
            draft = rng.integers(1, vocab, size=gamma).astype(np.int64)
            q_sparse = []
            for _ in range(gamma):
                idx = rng.choice(vocab, size=8, replace=False) \
                    .astype(np.int32)
                val = rng.random(8)
                q_sparse.append((idx, (val / val.sum()).astype(np.float16)))
            per_slot.append((unc, draft, q_sparse))
        work.append(per_slot)
    return prompts, work


def run_mode(vocab: int, slots: int, rounds: int, *, fused: bool,
             sampling: str, gamma: int = 4, chunk: int = 32,
             seed: int = 11) -> dict:
    from repro.serving.scheduler import (PrefillRequest, VerifyRequest,
                                         VerificationAwareScheduler)

    engine = build_engine(vocab, slots)
    sched = VerificationAwareScheduler(engine, chunk=chunk, fused=fused,
                                       rng=np.random.default_rng(seed))
    prompts, work = _make_workload(slots, rounds + 1, gamma, vocab, seed)

    slot_of = {}
    for i, p in enumerate(prompts):
        sched.submit_prefill(PrefillRequest(i + 1, p))
    done = 0
    while done < slots:
        for ev in sched.run_iteration():
            slot_of[ev.req_id - 1] = ev.slot
            done += 1

    rid = slots
    results = []

    def run_round(per_slot):
        nonlocal rid
        want = set()
        for i, (unc, draft, q_sparse) in enumerate(per_slot):
            rid += 1
            want.add(rid)
            sched.submit_verify(VerifyRequest(
                rid, slot_of[i], uncached=unc, draft=draft,
                q_sparse=q_sparse, sampling=sampling))
        out = []
        while want:
            for ev in sched.run_iteration():
                want.discard(ev.req_id)
                out.append((ev.req_id, ev.result))
        return out

    run_round(work[0])                      # warmup: jit + verifier paths
    iters0 = sched.verify_iterations
    bytes0 = engine.bytes_to_host
    sim0 = sched.sim_ms
    t0 = time.perf_counter()
    for per_slot in work[1:]:
        results.extend(run_round(per_slot))
    wall_s = time.perf_counter() - t0
    n_iters = sched.verify_iterations - iters0
    n_bytes = engine.bytes_to_host - bytes0
    sim_ms = sched.sim_ms - sim0

    return dict(
        engine="fused" if fused else "legacy",
        sampling=sampling,
        verify_iterations=n_iters,
        # measured host wall time per scheduler iteration (engine step +
        # host verifier).  NOTE: CPU jax aliases device/host buffers, so
        # the legacy path's 32 MiB/iter "transfer" is free here; on real
        # accelerators it crosses the interconnect, which the modeled
        # number below charges at CloudLatencyModel.host_link_gbps.
        mean_iter_ms=wall_s / max(n_iters, 1) * 1e3,
        # modeled serving time per iteration (the repo's time axis for
        # every TBT/makespan number): compute + host-link transfer
        mean_iter_ms_modeled=sim_ms / max(n_iters, 1),
        host_bytes_per_verify_iter=n_bytes / max(n_iters, 1),
        wall_s=wall_s,
        mean_verify_occupancy=sched.mean_verify_occupancy,
        compile_stats=engine.compile_stats,
        results=[(r, res.n_accepted, res.tokens) for r, res in results],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--vocab", type=int, default=32_768)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--out", default="benchmarks/BENCH_hotpath.json")
    args = ap.parse_args()
    rounds = 2 if args.fast else args.rounds

    rows = []
    identical = {}
    for sampling in ("greedy", "sample"):
        per_mode = {}
        for fused in (False, True):
            r = run_mode(args.vocab, args.slots, rounds, fused=fused,
                         sampling=sampling)
            per_mode[r["engine"]] = r
            print(f"{sampling:6s} {r['engine']:6s} "
                  f"iter={r['verify_iterations']} "
                  f"ms/iter={r['mean_iter_ms']:.1f} "
                  f"B/iter={r['host_bytes_per_verify_iter']:.0f}",
                  flush=True)
        if sampling == "greedy":
            identical["greedy_identical"] = (
                per_mode["fused"]["results"] == per_mode["legacy"]["results"])
            assert identical["greedy_identical"], \
                "fused greedy verification diverged from the host-numpy path"
        for r in per_mode.values():
            r.pop("results")
            rows.append(r)

    by = {(r["sampling"], r["engine"]): r for r in rows}
    reduction = dict(
        bytes=(by[("greedy", "legacy")]["host_bytes_per_verify_iter"]
               / by[("greedy", "fused")]["host_bytes_per_verify_iter"]),
        iter_time=(by[("greedy", "legacy")]["mean_iter_ms"]
                   / by[("greedy", "fused")]["mean_iter_ms"]),
        iter_time_modeled=(by[("greedy", "legacy")]["mean_iter_ms_modeled"]
                           / by[("greedy", "fused")]["mean_iter_ms_modeled"]),
    )
    res = dict(vocab=args.vocab, slots=args.slots, chunk=32, gamma=4,
               rounds=rounds, verify_top_k=8, rows=rows,
               reduction=reduction, **identical)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"bytes reduction {reduction['bytes']:.1f}x, "
          f"iter-time {reduction['iter_time']:.2f}x wall / "
          f"{reduction['iter_time_modeled']:.2f}x modeled; wrote {args.out}")


if __name__ == "__main__":
    main()
