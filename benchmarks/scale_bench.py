"""Scale benchmark: N concurrent device streams sharing one cloud
verifier under the SyneraServer event loop (ROADMAP: heavy traffic /
batching / async).

Three sweeps:

1. **Batching sweep** (``rows``): for each stream count the same request
   set is served twice on a fresh slot state: sequentially
   (``concurrency=1``, the old blocking semantics) and concurrently
   (``concurrency=N``).  Greedy token streams are identical by
   construction (asserted); what changes is packing:

   * verify-iteration batch occupancy (slots fed per iteration)
   * packed tokens per iteration
   * total scheduler iterations and cloud makespan (shared sim clock)
   * per-stream mean/p95 TBT (includes real cross-stream queueing)
   * estimated cloud cost (paper §6.1)

2. **Cache sweep** (``cache_rows``): dense vs paged KV cache at
   oversubscribed concurrency (more sessions than engine slots, the
   waiting-queue path).  Token streams are asserted identical; what
   changes is memory: the dense engine reserves ``slots x s_max``
   regardless of live lengths, the paged engine's footprint is its
   peak block usage — reported as *cache bytes per served token*.

3. **Shared-prefix sweep** (``shared_prefix_sweep``): N concurrent
   streams whose prompts share a long common system prefix, served on a
   paged engine with and without ``share_prefix``.  Outputs are asserted
   byte-identical (and identical to dense); what changes is peak pool
   usage — the common prefix's full blocks are allocated once and
   ref-counted into every stream's block table instead of once per
   stream.

4. **Preemption-pressure sweep** (``preempt_sweep``): recompute vs swap
   vs slo-aware eviction on a pool sized to force preemption, short vs
   long prompt prefixes at oversubscribed concurrency.  Outputs are
   asserted byte-identical across dispositions; the headline column is
   ``preempted_refed_tokens`` — recompute refeeds the victim's whole
   prefix, the host swap tier restores it bit-identical and refeeds
   nothing.

5. **Cross-session reuse sweep** (``cross_session_sweep``): N
   sequential non-overlapping waves of sessions sharing a system
   prompt, served with retention (cached-free LRU) and with the
   content-addressed host store.  Outputs are asserted byte-identical
   to retention-off paged and dense; wave 2+ must feed at least the
   shared-prefix length fewer prefill tokens, and the swap variant must
   adopt blocks from the host store.

6. **Fleet routing sweep** (``fleet_sweep``): R independent cloud
   replicas behind a ``ReplicaRouter``, serving streams that share a
   system prompt, once per routing policy on a fresh fleet.  Outputs
   are asserted byte-identical across all policies and to a
   single-engine run; prefix-affinity must feed fewer total prefill
   tokens than round-robin (it concentrates the shared prefix on the
   replica already holding it, round-robin re-prefills it once per
   replica).

7. **Stall-attribution sweep** (``stall_sweep``): the unified tracer
   (serving/trace.py) attached at high concurrency on a tight pool,
   host swap off and on.  Outputs are asserted byte-identical to an
   untraced run (tracing is passive); reported per row are the
   exclusive stall buckets and their shares of total stream wall time,
   asserted to sum to it.

Usage:
  PYTHONPATH=src:. python -m benchmarks.scale_bench [--fast] \
      [--streams 1,2,4,8] [--concurrency 8,32,128] \
      [--shared-streams 4,8] [--prefix-blocks 4] \
      [--preempt-concurrency 8,32,128] \
      [--cross-waves 3] [--cross-streams 2] \
      [--fleet-replicas 4] [--fleet-streams 64] \
      [--stall-concurrency 8,32,128] \
      [--out benchmarks/BENCH_scale.json]

Skipped sweeps ('' as the list) keep their previously written section
in the output JSON, so one sweep can be refreshed without re-running
the others.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_sweep(streams=(1, 2, 4, 8), max_new: int = 32, slots: int = 8,
              budget_all: bool = True) -> dict:
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    eng = PC.make_engine(llm_cfg, llm_p, slots=slots)

    rows = []
    for n in streams:
        evalset = PC.eval_set(task, n, seed=17)
        prompts = [p for p, _ in evalset]

        t0 = time.time()
        r_seq = SY.run_synera(dev, eng, prompts, max_new, concurrency=1)
        t_seq = time.time() - t0
        seq = r_seq.extras["scheduler"]

        t0 = time.time()
        r_con = SY.run_synera(dev, eng, prompts, max_new,
                              concurrency=min(n, slots))
        t_con = time.time() - t0
        con = r_con.extras["scheduler"]

        assert r_con.outputs == r_seq.outputs, \
            "concurrent serving must not change greedy token streams"

        tbts = [m.tbt_ms for m in r_con.metrics]
        n_tokens = sum(len(m.tokens) for m in r_con.metrics)
        rows.append(dict(
            streams=n,
            occupancy=con["mean_verify_occupancy"],
            max_occupancy=con["max_verify_occupancy"],
            packed_tokens_per_iter=con["mean_packed_tokens"],
            iterations=con["iterations"],
            iterations_sequential=seq["iterations"],
            makespan_ms=con["sim_ms"],
            makespan_sequential_ms=seq["sim_ms"],
            tbt_mean_ms=float(np.mean(tbts)),
            tbt_p95_ms=float(np.quantile(tbts, 0.95)),
            tbt_sequential_ms=r_seq.tbt_ms,
            cost=r_con.cost,
            tokens=n_tokens,
            wall_s_sequential=t_seq,
            wall_s_concurrent=t_con,
        ))
        print(f"streams={n:2d} occupancy={rows[-1]['occupancy']:.2f} "
              f"packed_tok/iter={rows[-1]['packed_tokens_per_iter']:.1f} "
              f"iters={rows[-1]['iterations']} "
              f"(seq {rows[-1]['iterations_sequential']}) "
              f"tbt={rows[-1]['tbt_mean_ms']:.1f}ms "
              f"p95={rows[-1]['tbt_p95_ms']:.1f}ms", flush=True)
    return dict(slots=slots, max_new=max_new, rows=rows)


def run_cache_sweep(concurrency=(8, 32, 128), max_new: int = 8,
                    slots: int = 8, block_size: int = 8) -> dict:
    """Dense vs paged cache at oversubscribed concurrency.

    Each stream count is served once on a dense engine and once on a
    paged engine (same slots; the paged pool is left at dense capacity —
    the saving reported is *peak blocks actually touched*, which is what
    a right-sized pool must hold).  Outputs are asserted identical.
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)

    rows = []
    for n in concurrency:
        evalset = PC.eval_set(task, n, seed=23)
        prompts = [p for p, _ in evalset]

        eng_d = PC.make_engine(llm_cfg, llm_p, slots=slots)
        t0 = time.time()
        r_d = SY.run_synera(dev, eng_d, prompts, max_new, concurrency=n)
        t_d = time.time() - t0
        st_d = r_d.extras["scheduler"]

        eng_p = PC.make_engine(llm_cfg, llm_p, slots=slots,
                               cache_impl="paged", block_size=block_size)
        t0 = time.time()
        r_p = SY.run_synera(dev, eng_p, prompts, max_new, concurrency=n)
        t_p = time.time() - t0
        st_p = r_p.extras["scheduler"]

        assert r_p.outputs == r_d.outputs, \
            "paged serving must not change greedy token streams"

        tokens = sum(len(m.tokens) for m in r_p.metrics)
        # dense must reserve the full slots x s_max cache; a right-sized
        # paged pool holds the peak block usage
        dense_bytes = st_d["kv_cache_bytes"]
        paged_bytes = st_p["kv_bytes_peak"]
        rows.append(dict(
            concurrency=n,
            tokens=tokens,
            dense_cache_bytes=dense_bytes,
            paged_cache_bytes_peak=paged_bytes,
            dense_bytes_per_token=dense_bytes / max(tokens, 1),
            paged_bytes_per_token=paged_bytes / max(tokens, 1),
            bytes_per_token_ratio=dense_bytes / max(paged_bytes, 1),
            peak_used_blocks=st_p["peak_used_blocks"],
            n_blocks=st_p["n_blocks"],
            preemptions=st_p["preemptions"],
            makespan_dense_ms=st_d["sim_ms"],
            makespan_paged_ms=st_p["sim_ms"],
            wall_s_dense=t_d,
            wall_s_paged=t_p,
        ))
        print(f"concurrency={n:3d} dense={dense_bytes/2**20:.1f}MiB "
              f"paged_peak={paged_bytes/2**20:.1f}MiB "
              f"({rows[-1]['bytes_per_token_ratio']:.1f}x) "
              f"blocks={st_p['peak_used_blocks']}/{st_p['n_blocks']} "
              f"preempt={st_p['preemptions']}", flush=True)
    return dict(slots=slots, max_new=max_new, block_size=block_size,
                rows=rows)


def run_shared_prefix_sweep(streams=(4, 8), max_new: int = 8,
                            slots: int = 8, block_size: int = 8,
                            prefix_blocks: int = 4,
                            suffix_tokens: int = 8) -> dict:
    """Prefix-sharing on/off at full-slot concurrency with a common
    system prompt of ``prefix_blocks`` full blocks per stream.

    The sharing run must dedupe exactly those blocks across the
    co-resident streams: peak pool usage drops by
    ``prefix_blocks x (streams - 1)`` (asserted as a >= bound; outputs
    asserted byte-identical to the non-sharing paged run and to dense).
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    rng = np.random.default_rng(37)
    vocab = slm_cfg.vocab
    common = [int(t) for t in rng.integers(1, vocab - 1,
                                           prefix_blocks * block_size)]

    rows = []
    for n in streams:
        prompts = [common + [int(t) for t in rng.integers(1, vocab - 1,
                                                          suffix_tokens)]
                   for _ in range(n)]
        conc = min(n, slots)

        eng_d = PC.make_engine(llm_cfg, llm_p, slots=slots)
        r_d = SY.run_synera(dev, eng_d, prompts, max_new, concurrency=conc)

        eng_off = PC.make_engine(llm_cfg, llm_p, slots=slots,
                                 cache_impl="paged", block_size=block_size)
        t0 = time.time()
        r_off = SY.run_synera(dev, eng_off, prompts, max_new,
                              concurrency=conc)
        t_off = time.time() - t0
        st_off = r_off.extras["scheduler"]

        eng_on = PC.make_engine(llm_cfg, llm_p, slots=slots,
                                cache_impl="paged", block_size=block_size,
                                share_prefix=True)
        t0 = time.time()
        r_on = SY.run_synera(dev, eng_on, prompts, max_new,
                             concurrency=conc)
        t_on = time.time() - t0
        st_on = r_on.extras["scheduler"]

        assert r_off.outputs == r_d.outputs, \
            "paged serving must not change greedy token streams"
        assert r_on.outputs == r_d.outputs, \
            "prefix sharing must not change greedy token streams"
        saved = st_off["peak_used_blocks"] - st_on["peak_used_blocks"]
        assert saved >= prefix_blocks * (conc - 1), (st_off, st_on)

        rows.append(dict(
            streams=n,
            concurrency=conc,
            prefix_tokens=len(common),
            prefix_blocks=prefix_blocks,
            peak_used_blocks_noshare=st_off["peak_used_blocks"],
            peak_used_blocks_share=st_on["peak_used_blocks"],
            saved_peak_blocks=saved,
            dedupe_hit_blocks=st_on["dedupe_hit_blocks"],
            cow_copies=st_on["cow_copies"],
            kv_bytes_peak_noshare=st_off["kv_bytes_peak"],
            kv_bytes_peak_share=st_on["kv_bytes_peak"],
            prefill_iterations=st_on["prefill_iterations"],
            makespan_noshare_ms=st_off["sim_ms"],
            makespan_share_ms=st_on["sim_ms"],
            wall_s_noshare=t_off,
            wall_s_share=t_on,
        ))
        print(f"streams={n:3d} peak_blocks {st_off['peak_used_blocks']}"
              f"->{st_on['peak_used_blocks']} (saved {saved}, "
              f">= {prefix_blocks * (conc - 1)} required) "
              f"dedupe={st_on['dedupe_hit_blocks']} "
              f"cow={st_on['cow_copies']}", flush=True)
    return dict(slots=slots, max_new=max_new, block_size=block_size,
                prefix_blocks=prefix_blocks, suffix_tokens=suffix_tokens,
                rows=rows)


def run_preempt_sweep(concurrency=(8, 32, 128), max_new: int = 6,
                      slots: int = 8, block_size: int = 8,
                      long_tokens: int = 40, short_tokens: int = 8) -> dict:
    """Preemption pressure: recompute vs swap vs slo-aware eviction on a
    pool sized so concurrent streams force evictions (ISSUE 5).

    For each stream count and prompt profile (short vs long prefixes)
    the same request set is served four ways on fresh pool state:

    * roomy pool (dense-capacity blocks, no preemption — the reference);
    * tight pool, recompute-eviction (victims refeed their whole prefix);
    * tight pool, host swap tier (victims park in host RAM, restore
      bit-identical, refeed nothing);
    * tight pool, swap + slo-aware victim selection (every other stream
      carries a deadline; no-SLO streams absorb the evictions).

    Outputs are asserted byte-identical across all four.  The headline
    column is ``preempted_refed_tokens``: recompute pays the re-prefill
    (large for long prefixes), swap pays only the modeled D2H+H2D bytes.
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving.swap import StreamSLO
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    rng = np.random.default_rng(43)
    vocab = slm_cfg.vocab

    def engines_for(plen):
        # a tight pool: ~3 live streams' worth of blocks on 8 slots
        per_stream = -(-(plen + max_new + 8) // block_size) + 1
        pool = 3 * per_stream
        mk = lambda **kw: PC.make_engine(llm_cfg, llm_p, slots=slots,
                                         cache_impl="paged",
                                         block_size=block_size, **kw)
        # the slo config differs only in scheduler policy/budgets, so it
        # shares the swap engine (drained between runs; jit is per-engine)
        swap_eng = mk(pool_blocks=pool, swap=True)
        return pool, dict(recompute=mk(pool_blocks=pool),
                          swap=swap_eng, slo=swap_eng)

    eng_roomy = PC.make_engine(llm_cfg, llm_p, slots=slots,
                               cache_impl="paged", block_size=block_size)
    profiles = {p: engines_for(t)
                for p, t in (("short", short_tokens), ("long", long_tokens))}

    rows = []
    for n in concurrency:
        for profile, plen in (("short", short_tokens),
                              ("long", long_tokens)):
            prompts = [[int(t) for t in rng.integers(1, vocab - 1, plen)]
                       for _ in range(n)]
            pool, engs = profiles[profile]
            r_ref = SY.run_synera(dev, eng_roomy, prompts, max_new,
                                  concurrency=n)
            slos = [StreamSLO(deadline_ms=5e3) if i % 2 == 0 else None
                    for i in range(n)]
            row = dict(concurrency=n, profile=profile,
                       prompt_tokens=plen, pool_blocks=pool,
                       tokens=sum(len(m.tokens) for m in r_ref.metrics))
            for name, eng in engs.items():
                # engines are reused across rows but the swap byte
                # counters are engine-cumulative: report per-run deltas
                sw = eng.swap_manager
                out0 = sw.swap_out_bytes if sw else 0
                in0 = sw.swap_in_bytes if sw else 0
                t0 = time.time()
                r = SY.run_synera(
                    dev, eng, prompts, max_new, concurrency=n,
                    preempt_policy="slo-aware" if name == "slo" else None,
                    slos=slos if name == "slo" else None)
                wall = time.time() - t0
                st = r.extras["scheduler"]
                assert r.outputs == r_ref.outputs, \
                    f"{name} eviction must not change greedy token streams"
                row[name] = dict(
                    preemptions=st["preemptions"],
                    recompute_evictions=st["recompute_evictions"],
                    swap_evictions=st["swap_evictions"],
                    preempted_refed_tokens=st["preempted_refed_tokens"],
                    swap_out_bytes=st["swap_out_bytes"] - out0,
                    swap_in_bytes=st["swap_in_bytes"] - in0,
                    makespan_ms=st["sim_ms"],
                    wall_s=wall)
            rows.append(row)
            print(f"conc={n:3d} {profile:5s} pool={pool:3d} "
                  f"refed recompute={row['recompute']['preempted_refed_tokens']} "
                  f"swap={row['swap']['preempted_refed_tokens']} "
                  f"slo={row['slo']['preempted_refed_tokens']} "
                  f"(swap_ev {row['swap']['swap_evictions']}, "
                  f"slo_ev {row['slo']['swap_evictions']})", flush=True)
    return dict(slots=slots, max_new=max_new, block_size=block_size,
                long_tokens=long_tokens, short_tokens=short_tokens,
                rows=rows)


def run_cross_session_sweep(waves: int = 3, streams: int = 2,
                            max_new: int = 6, slots: int = 4,
                            block_size: int = 8,
                            prefix_blocks: int = 4,
                            suffix_tokens: int = 8) -> dict:
    """Cross-session prefix reuse (ISSUE 8): N sequential,
    *non-overlapping* waves of sessions sharing a system prompt.

    Four variants serve every wave on persistent engine state:

    * dense (the oracle) and paged retention-off (each wave re-prefills
      the full system prompt);
    * ``retain_prefix``: wave 1's released chain parks on the
      cached-free LRU and wave 2+ revives it on-device;
    * ``share_prefix + swap + host_dedupe`` (retention off): wave 1's
      chain is demoted to the content-addressed host store on release
      and wave 2+ *adopts* it back by H2D scatter.

    Asserted per wave: outputs byte-identical across all four; from
    wave 2 on, both caching variants feed at least the shared-prefix
    length fewer prefill tokens than retention-off, and the swap
    variant's adoptions come from the host store (zero live sharers).
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    rng = np.random.default_rng(53)
    vocab = slm_cfg.vocab
    prefix_len = prefix_blocks * block_size
    common = [int(t) for t in rng.integers(1, vocab - 1, prefix_len)]

    mk = lambda **kw: PC.make_engine(llm_cfg, llm_p, slots=slots,
                                     cache_impl="paged",
                                     block_size=block_size, **kw)
    eng_dense = PC.make_engine(llm_cfg, llm_p, slots=slots)
    eng_off = mk()
    eng_ret = mk(retain_prefix=True)
    eng_hsw = mk(share_prefix=True, swap=True, host_dedupe=True)

    rows, adopted_prev = [], 0
    for w in range(1, waves + 1):
        prompts = [common + [int(t) for t in rng.integers(
                       1, vocab - 1, suffix_tokens)]
                   for _ in range(streams)]
        r_d = SY.run_synera(dev, eng_dense, prompts, max_new,
                            concurrency=streams)
        r_off = SY.run_synera(dev, eng_off, prompts, max_new,
                              concurrency=streams)
        r_ret = SY.run_synera(dev, eng_ret, prompts, max_new,
                              concurrency=streams)
        r_hsw = SY.run_synera(dev, eng_hsw, prompts, max_new,
                              concurrency=streams)
        for name, r in (("paged", r_off), ("retain", r_ret),
                        ("host_swap", r_hsw)):
            assert r.outputs == r_d.outputs, \
                f"{name} wave {w} must not change greedy token streams"
        fed_off = r_off.extras["scheduler"]["prefill_fed_tokens"]
        fed_ret = r_ret.extras["scheduler"]["prefill_fed_tokens"]
        fed_hsw = r_hsw.extras["scheduler"]["prefill_fed_tokens"]
        adopted = eng_hsw.swap_manager.host_adopted_blocks
        row = dict(wave=w, streams=streams, prefix_tokens=prefix_len,
                   prefill_fed_tokens_off=fed_off,
                   prefill_fed_tokens_retain=fed_ret,
                   prefill_fed_tokens_host_swap=fed_hsw,
                   revived_blocks=eng_ret.allocator.revived_blocks,
                   tail_shared_tokens=(
                       eng_ret.allocator.tail_shared_tokens),
                   host_adopted_blocks_wave=adopted - adopted_prev,
                   host_store_blocks=eng_hsw.swap_manager.host_store_blocks)
        adopted_prev = adopted
        if w >= 2:
            assert fed_off - fed_ret >= prefix_len, row
            assert fed_off - fed_hsw >= prefix_len, row
            assert row["host_adopted_blocks_wave"] > 0, row
        rows.append(row)
        print(f"wave={w} fed off={fed_off} retain={fed_ret} "
              f"host_swap={fed_hsw} "
              f"adopted={row['host_adopted_blocks_wave']} "
              f"store={row['host_store_blocks']}", flush=True)
    return dict(waves=waves, streams=streams, max_new=max_new,
                slots=slots, block_size=block_size,
                prefix_blocks=prefix_blocks, suffix_tokens=suffix_tokens,
                rows=rows)


def run_fleet_sweep(replicas=(4,), streams: int = 64, max_new: int = 4,
                    slots: int = 4, block_size: int = 8,
                    prefix_blocks: int = 4, suffix_tokens: int = 8,
                    concurrency: int = 8) -> dict:
    """Multi-replica routing (ISSUE 9): R independent cloud replicas
    behind a ``ReplicaRouter``, all streams sharing a system prompt of
    ``prefix_blocks`` full blocks.

    Workload shape: one seed stream, then the remaining streams
    admitted ``concurrency`` at a time — so every post-seed placement
    probes a fleet that already holds the prefix somewhere.  Each
    policy gets a FRESH fleet of retain+share_prefix paged engines.

    Asserted: outputs byte-identical across all policies and to a
    single-engine run; prefix-affinity feeds strictly fewer total
    prefill tokens than round-robin.
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY
    from repro.serving.router import ROUTE_POLICIES, ReplicaRouter
    from repro.serving.server import build_fleet

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    rng = np.random.default_rng(61)
    vocab = slm_cfg.vocab
    common = [int(t) for t in rng.integers(1, vocab - 1,
                                           prefix_blocks * block_size)]
    prompts = [common + [int(t) for t in rng.integers(1, vocab - 1,
                                                      suffix_tokens)]
               for _ in range(streams)]

    mk = lambda: PC.make_engine(llm_cfg, llm_p, slots=slots,
                                cache_impl="paged", block_size=block_size,
                                share_prefix=True, retain_prefix=True)

    r_ref = SY.run_synera(dev, mk(), prompts, max_new, concurrency=1)
    ref_out = [[int(t) for t in o] for o in r_ref.outputs]

    rows = []
    for n_rep in replicas:
        row = dict(replicas=n_rep, streams=streams,
                   prefix_tokens=len(common), concurrency=concurrency)
        for policy in ROUTE_POLICIES:
            router = ReplicaRouter(
                build_fleet(dev, [mk() for _ in range(n_rep)]),
                policy=policy)
            t0 = time.time()
            metrics = router.serve(prompts[:1], max_new, concurrency=1)
            metrics += router.serve(prompts[1:], max_new,
                                    concurrency=concurrency)
            wall = time.time() - t0
            outs = [[int(t) for t in m.tokens] for m in metrics]
            assert outs == ref_out, \
                f"{policy} routing must not change greedy token streams"
            st = router.stats()
            touched = {router.owner[id(s)] for s in router.sessions}
            row[policy] = dict(
                prefill_fed_tokens=st["prefill_fed_tokens"],
                affinity_hits=st["affinity_hits"],
                revived_blocks=st["revived_blocks"],
                dedupe_hit_blocks=st["dedupe_hit_blocks"],
                replicas_touched=len(touched),
                degraded_streams=st["degraded_streams"],
                wall_s=wall)
        fed_aff = row["prefix-affinity"]["prefill_fed_tokens"]
        fed_rr = row["round-robin"]["prefill_fed_tokens"]
        assert fed_aff < fed_rr, row
        rows.append(row)
        print(f"replicas={n_rep} streams={streams} prefill_fed "
              f"rr={fed_rr} ll="
              f"{row['least-loaded']['prefill_fed_tokens']} "
              f"affinity={fed_aff} "
              f"(hits={row['prefix-affinity']['affinity_hits']}, "
              f"touched {row['prefix-affinity']['replicas_touched']} vs "
              f"rr {row['round-robin']['replicas_touched']})", flush=True)
    return dict(streams=streams, max_new=max_new, slots=slots,
                block_size=block_size, prefix_blocks=prefix_blocks,
                suffix_tokens=suffix_tokens, rows=rows)


def run_stall_sweep(concurrency=(8, 32, 128), max_new: int = 6,
                    slots: int = 8, block_size: int = 8) -> dict:
    """Stall-time attribution under load (ISSUE 10): each stream count
    is served with the unified tracer attached (serving/trace.py) on a
    paged pool tight enough to force queueing/preemption, with the host
    swap tier off and on.

    Reported per row: the fleet's exclusive stall buckets (device /
    cloud / link / queue / batch_wait / swap / preempted / other) and
    their shares of total stream wall time — asserted to sum to it
    within float tolerance.  Tracing is passive: outputs are asserted
    byte-identical to an untraced run on identical engine state.
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    BUCKETS = ("device", "cloud", "link", "queue", "batch_wait", "swap",
               "preempted", "other")
    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)

    rows = []
    for n in concurrency:
        evalset = PC.eval_set(task, n, seed=31)
        prompts = [p for p, _ in evalset]
        plen = max(len(p) for p in prompts)
        # a tight pool: ~3 live streams' worth of blocks (preempt_sweep
        # sizing) so oversubscription shows up in the wait/swap buckets
        per_stream = -(-(plen + max_new + 8) // block_size) + 1
        pool = 3 * per_stream
        mk = lambda **kw: PC.make_engine(llm_cfg, llm_p, slots=slots,
                                         cache_impl="paged",
                                         block_size=block_size,
                                         pool_blocks=pool, **kw)
        # outputs are invariant across swap on/off (tested elsewhere),
        # so one untraced run is the byte-identity reference for both
        r_ref = SY.run_synera(dev, mk(), prompts, max_new, concurrency=n)
        for swap in (False, True):
            t0 = time.time()
            r = SY.run_synera(dev, mk(swap=swap), prompts, max_new,
                              concurrency=n, trace=True)
            wall_s = time.time() - t0
            assert r.outputs == r_ref.outputs, \
                "tracing must not change greedy token streams"
            st = r.extras["scheduler"]
            wall = st["stall_wall_ms"]
            buckets = {b: st[f"stall_{b}_ms"] for b in BUCKETS}
            total = sum(buckets.values())
            assert abs(total - wall) <= 1e-6 * max(1.0, wall), \
                (total, wall)
            rows.append(dict(
                concurrency=n, swap=swap, pool_blocks=pool,
                stall_wall_ms=wall,
                buckets_ms=buckets,
                bucket_shares={b: v / max(wall, 1e-9)
                               for b, v in buckets.items()},
                preemptions=st["preemptions"],
                swap_evictions=st["swap_evictions"],
                makespan_ms=st["sim_ms"],
                wall_s=wall_s))
            shares = rows[-1]["bucket_shares"]
            print(f"conc={n:3d} swap={int(swap)} pool={pool:3d} "
                  f"device={shares['device']:.0%} "
                  f"cloud={shares['cloud']:.0%} "
                  f"wait={shares['batch_wait']:.0%} "
                  f"queue={shares['queue']:.0%} "
                  f"swap={shares['swap']:.0%} "
                  f"preempt={shares['preempted']:.0%}", flush=True)
    return dict(slots=slots, max_new=max_new, block_size=block_size,
                rows=rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--streams", default="1,2,4,8")
    ap.add_argument("--concurrency", default="8,32,128",
                    help="stream counts for the dense-vs-paged cache "
                         "sweep ('' to skip)")
    ap.add_argument("--shared-streams", default="4,8",
                    help="stream counts for the shared-prefix sweep "
                         "('' to skip)")
    ap.add_argument("--preempt-concurrency", default="8,32,128",
                    help="stream counts for the preemption-pressure "
                         "recompute/swap/slo sweep ('' to skip)")
    ap.add_argument("--prefix-blocks", type=int, default=4,
                    help="common system-prefix length in full KV blocks")
    ap.add_argument("--cross-waves", default="3",
                    help="sequential non-overlapping waves for the "
                         "cross-session reuse sweep ('' to skip)")
    ap.add_argument("--cross-streams", type=int, default=2,
                    help="sessions per wave in the cross-session sweep")
    ap.add_argument("--fleet-replicas", default="4",
                    help="replica counts for the multi-replica routing "
                         "sweep ('' to skip)")
    ap.add_argument("--fleet-streams", type=int, default=64,
                    help="streams per fleet-sweep row")
    ap.add_argument("--stall-concurrency", default="8,32,128",
                    help="stream counts for the traced stall-"
                         "attribution sweep, swap off/on per count "
                         "('' to skip)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--out", default="benchmarks/BENCH_scale.json")
    args = ap.parse_args()
    # skipped sweeps keep their previously written section
    res = {}
    try:
        with open(args.out) as f:
            res = json.load(f)
    except (OSError, ValueError):
        pass
    if args.streams:
        streams = tuple(int(s) for s in args.streams.split(","))
        res.update(run_sweep(streams=streams,
                             max_new=16 if args.fast else 32,
                             slots=args.slots))
    if args.concurrency:
        conc = tuple(int(s) for s in args.concurrency.split(","))
        res["cache_sweep"] = run_cache_sweep(
            concurrency=conc, max_new=4 if args.fast else 8,
            slots=args.slots, block_size=args.block_size)
    if args.shared_streams:
        shared = tuple(int(s) for s in args.shared_streams.split(","))
        res["shared_prefix_sweep"] = run_shared_prefix_sweep(
            streams=shared, max_new=4 if args.fast else 8,
            slots=args.slots, block_size=args.block_size,
            prefix_blocks=args.prefix_blocks)
    if args.preempt_concurrency:
        conc = tuple(int(s) for s in args.preempt_concurrency.split(","))
        res["preempt_sweep"] = run_preempt_sweep(
            concurrency=conc, max_new=4 if args.fast else 6,
            slots=args.slots, block_size=args.block_size)
    if args.cross_waves:
        res["cross_session_sweep"] = run_cross_session_sweep(
            waves=int(args.cross_waves), streams=args.cross_streams,
            max_new=4 if args.fast else 6,
            block_size=args.block_size,
            prefix_blocks=args.prefix_blocks)
    if args.fleet_replicas:
        reps = tuple(int(s) for s in args.fleet_replicas.split(","))
        res["fleet_sweep"] = run_fleet_sweep(
            replicas=reps,
            streams=16 if args.fast else args.fleet_streams,
            block_size=args.block_size,
            prefix_blocks=args.prefix_blocks)
    if args.stall_concurrency:
        conc = tuple(int(s) for s in args.stall_concurrency.split(","))
        res["stall_sweep"] = run_stall_sweep(
            concurrency=conc, max_new=4 if args.fast else 6,
            slots=args.slots, block_size=args.block_size)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
