"""Scale benchmark: N concurrent device streams sharing one cloud
verifier under the SyneraServer event loop (ROADMAP: heavy traffic /
batching / async).

Two sweeps:

1. **Batching sweep** (``rows``): for each stream count the same request
   set is served twice on a fresh slot state: sequentially
   (``concurrency=1``, the old blocking semantics) and concurrently
   (``concurrency=N``).  Greedy token streams are identical by
   construction (asserted); what changes is packing:

   * verify-iteration batch occupancy (slots fed per iteration)
   * packed tokens per iteration
   * total scheduler iterations and cloud makespan (shared sim clock)
   * per-stream mean/p95 TBT (includes real cross-stream queueing)
   * estimated cloud cost (paper §6.1)

2. **Cache sweep** (``cache_rows``): dense vs paged KV cache at
   oversubscribed concurrency (more sessions than engine slots, the
   waiting-queue path).  Token streams are asserted identical; what
   changes is memory: the dense engine reserves ``slots x s_max``
   regardless of live lengths, the paged engine's footprint is its
   peak block usage — reported as *cache bytes per served token*.

Usage:
  PYTHONPATH=src:. python -m benchmarks.scale_bench [--fast] \
      [--streams 1,2,4,8] [--concurrency 8,32,128] \
      [--out benchmarks/BENCH_scale.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_sweep(streams=(1, 2, 4, 8), max_new: int = 32, slots: int = 8,
              budget_all: bool = True) -> dict:
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    eng = PC.make_engine(llm_cfg, llm_p, slots=slots)

    rows = []
    for n in streams:
        evalset = PC.eval_set(task, n, seed=17)
        prompts = [p for p, _ in evalset]

        t0 = time.time()
        r_seq = SY.run_synera(dev, eng, prompts, max_new, concurrency=1)
        t_seq = time.time() - t0
        seq = r_seq.extras["scheduler"]

        t0 = time.time()
        r_con = SY.run_synera(dev, eng, prompts, max_new,
                              concurrency=min(n, slots))
        t_con = time.time() - t0
        con = r_con.extras["scheduler"]

        assert r_con.outputs == r_seq.outputs, \
            "concurrent serving must not change greedy token streams"

        tbts = [m.tbt_ms for m in r_con.metrics]
        n_tokens = sum(len(m.tokens) for m in r_con.metrics)
        rows.append(dict(
            streams=n,
            occupancy=con["mean_verify_occupancy"],
            max_occupancy=con["max_verify_occupancy"],
            packed_tokens_per_iter=con["mean_packed_tokens"],
            iterations=con["iterations"],
            iterations_sequential=seq["iterations"],
            makespan_ms=con["sim_ms"],
            makespan_sequential_ms=seq["sim_ms"],
            tbt_mean_ms=float(np.mean(tbts)),
            tbt_p95_ms=float(np.quantile(tbts, 0.95)),
            tbt_sequential_ms=r_seq.tbt_ms,
            cost=r_con.cost,
            tokens=n_tokens,
            wall_s_sequential=t_seq,
            wall_s_concurrent=t_con,
        ))
        print(f"streams={n:2d} occupancy={rows[-1]['occupancy']:.2f} "
              f"packed_tok/iter={rows[-1]['packed_tokens_per_iter']:.1f} "
              f"iters={rows[-1]['iterations']} "
              f"(seq {rows[-1]['iterations_sequential']}) "
              f"tbt={rows[-1]['tbt_mean_ms']:.1f}ms "
              f"p95={rows[-1]['tbt_p95_ms']:.1f}ms", flush=True)
    return dict(slots=slots, max_new=max_new, rows=rows)


def run_cache_sweep(concurrency=(8, 32, 128), max_new: int = 8,
                    slots: int = 8, block_size: int = 8) -> dict:
    """Dense vs paged cache at oversubscribed concurrency.

    Each stream count is served once on a dense engine and once on a
    paged engine (same slots; the paged pool is left at dense capacity —
    the saving reported is *peak blocks actually touched*, which is what
    a right-sized pool must hold).  Outputs are asserted identical.
    """
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)

    rows = []
    for n in concurrency:
        evalset = PC.eval_set(task, n, seed=23)
        prompts = [p for p, _ in evalset]

        eng_d = PC.make_engine(llm_cfg, llm_p, slots=slots)
        t0 = time.time()
        r_d = SY.run_synera(dev, eng_d, prompts, max_new, concurrency=n)
        t_d = time.time() - t0
        st_d = r_d.extras["scheduler"]

        eng_p = PC.make_engine(llm_cfg, llm_p, slots=slots,
                               cache_impl="paged", block_size=block_size)
        t0 = time.time()
        r_p = SY.run_synera(dev, eng_p, prompts, max_new, concurrency=n)
        t_p = time.time() - t0
        st_p = r_p.extras["scheduler"]

        assert r_p.outputs == r_d.outputs, \
            "paged serving must not change greedy token streams"

        tokens = sum(len(m.tokens) for m in r_p.metrics)
        # dense must reserve the full slots x s_max cache; a right-sized
        # paged pool holds the peak block usage
        dense_bytes = st_d["kv_cache_bytes"]
        paged_bytes = st_p["kv_bytes_peak"]
        rows.append(dict(
            concurrency=n,
            tokens=tokens,
            dense_cache_bytes=dense_bytes,
            paged_cache_bytes_peak=paged_bytes,
            dense_bytes_per_token=dense_bytes / max(tokens, 1),
            paged_bytes_per_token=paged_bytes / max(tokens, 1),
            bytes_per_token_ratio=dense_bytes / max(paged_bytes, 1),
            peak_used_blocks=st_p["peak_used_blocks"],
            n_blocks=st_p["n_blocks"],
            preemptions=st_p["preemptions"],
            makespan_dense_ms=st_d["sim_ms"],
            makespan_paged_ms=st_p["sim_ms"],
            wall_s_dense=t_d,
            wall_s_paged=t_p,
        ))
        print(f"concurrency={n:3d} dense={dense_bytes/2**20:.1f}MiB "
              f"paged_peak={paged_bytes/2**20:.1f}MiB "
              f"({rows[-1]['bytes_per_token_ratio']:.1f}x) "
              f"blocks={st_p['peak_used_blocks']}/{st_p['n_blocks']} "
              f"preempt={st_p['preemptions']}", flush=True)
    return dict(slots=slots, max_new=max_new, block_size=block_size,
                rows=rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--streams", default="1,2,4,8")
    ap.add_argument("--concurrency", default="8,32,128",
                    help="stream counts for the dense-vs-paged cache "
                         "sweep ('' to skip)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--out", default="benchmarks/BENCH_scale.json")
    args = ap.parse_args()
    streams = tuple(int(s) for s in args.streams.split(","))
    res = run_sweep(streams=streams, max_new=16 if args.fast else 32,
                    slots=args.slots)
    if args.concurrency:
        conc = tuple(int(s) for s in args.concurrency.split(","))
        res["cache_sweep"] = run_cache_sweep(
            concurrency=conc, max_new=4 if args.fast else 8,
            slots=args.slots, block_size=args.block_size)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
