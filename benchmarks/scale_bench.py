"""Scale benchmark: N concurrent device streams sharing one cloud
verifier under the SyneraServer event loop (ROADMAP: heavy traffic /
batching / async).

For each stream count the same request set is served twice on a fresh
slot state: sequentially (``concurrency=1``, the old blocking
semantics) and concurrently (``concurrency=N``).  Greedy token streams
are identical by construction (asserted); what changes is packing:

  * verify-iteration batch occupancy (slots fed per iteration)
  * packed tokens per iteration
  * total scheduler iterations and cloud makespan (shared sim clock)
  * per-stream mean/p95 TBT (includes real cross-stream queueing)
  * estimated cloud cost (paper §6.1)

Usage:
  PYTHONPATH=src:. python -m benchmarks.scale_bench [--fast] \
      [--streams 1,2,4,8] [--out benchmarks/BENCH_scale.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_sweep(streams=(1, 2, 4, 8), max_new: int = 32, slots: int = 8,
              budget_all: bool = True) -> dict:
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    dev = PC.make_device(slm_cfg, slm_p,
                         policy=OffloadPolicy(mode="all"),
                         use_early_exit=False)
    eng = PC.make_engine(llm_cfg, llm_p, slots=slots)

    rows = []
    for n in streams:
        evalset = PC.eval_set(task, n, seed=17)
        prompts = [p for p, _ in evalset]

        t0 = time.time()
        r_seq = SY.run_synera(dev, eng, prompts, max_new, concurrency=1)
        t_seq = time.time() - t0
        seq = r_seq.extras["scheduler"]

        t0 = time.time()
        r_con = SY.run_synera(dev, eng, prompts, max_new,
                              concurrency=min(n, slots))
        t_con = time.time() - t0
        con = r_con.extras["scheduler"]

        assert r_con.outputs == r_seq.outputs, \
            "concurrent serving must not change greedy token streams"

        tbts = [m.tbt_ms for m in r_con.metrics]
        n_tokens = sum(len(m.tokens) for m in r_con.metrics)
        rows.append(dict(
            streams=n,
            occupancy=con["mean_verify_occupancy"],
            max_occupancy=con["max_verify_occupancy"],
            packed_tokens_per_iter=con["mean_packed_tokens"],
            iterations=con["iterations"],
            iterations_sequential=seq["iterations"],
            makespan_ms=con["sim_ms"],
            makespan_sequential_ms=seq["sim_ms"],
            tbt_mean_ms=float(np.mean(tbts)),
            tbt_p95_ms=float(np.quantile(tbts, 0.95)),
            tbt_sequential_ms=r_seq.tbt_ms,
            cost=r_con.cost,
            tokens=n_tokens,
            wall_s_sequential=t_seq,
            wall_s_concurrent=t_con,
        ))
        print(f"streams={n:2d} occupancy={rows[-1]['occupancy']:.2f} "
              f"packed_tok/iter={rows[-1]['packed_tokens_per_iter']:.1f} "
              f"iters={rows[-1]['iterations']} "
              f"(seq {rows[-1]['iterations_sequential']}) "
              f"tbt={rows[-1]['tbt_mean_ms']:.1f}ms "
              f"p95={rows[-1]['tbt_p95_ms']:.1f}ms", flush=True)
    return dict(slots=slots, max_new=max_new, rows=rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--streams", default="1,2,4,8")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--out", default="benchmarks/BENCH_scale.json")
    args = ap.parse_args()
    streams = tuple(int(s) for s in args.streams.split(","))
    res = run_sweep(streams=streams, max_new=16 if args.fast else 32,
                    slots=args.slots)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
