"""Benchmarks reproducing each of Synera's tables/figures on the trained
tiny SLM/LLM pair + synthetic tasks (exact ground-truth scoring).

One function per paper artifact:
  fig4   — SLM->LLM hit rate vs confidence; confidence CDF
  fig5   — quality vs offloading budget (importance vs random); imp CDF
  table4 — generation quality: edge / cloud / EdgeFM / Hybrid / Synera
  fig11  — latency (TBT) + ablations (w/o PI, conf-only, imp-only)
  fig12  — estimated cloud serving cost per method
  fig13  — bandwidth sweep with/without compression
  fig14  — quality/cost/latency vs budget trade-off
  fig15  — cloud scalability: verification latency vs request rate
  fig17  — layer-wise early-exit threshold sweep
  fig18  — verification-aware scheduler overhead vs budget
  sec65  — rejection-position prediction hit rate
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.early_exit import EarlyExitConfig
from repro.core.offload import OffloadPolicy
from repro.core.profiling import fit_profile
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.link import (CloudLatencyModel, CostModel,
                                DeviceLatencyModel, LinkModel)
from repro.serving import synergy as SY

GAMMA = 4
S_MAX = 192
PLEN = 40
GEN = 40


# ---------------------------------------------------------------------------
# Shared evaluation machinery
# ---------------------------------------------------------------------------

def eval_set(task, n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        seq, regimes = task.sample_sequence(PLEN + GEN, rng)
        out.append((list(map(int, seq[:PLEN])), regimes))
    return out


def score_outputs(task, evalset, outputs):
    scores = []
    for (prompt, regimes), toks in zip(evalset, outputs):
        full = np.array(prompt + [int(t) for t in toks])
        scores.append(task.score(full, regimes, PLEN))
    return {k: float(np.mean([s[k] for s in scores])) for k in scores[0]}


def make_device(slm_cfg, slm_p, policy=None, **kw):
    # wire_vocab: payload accounting at Llama-2 production vocab (§4.2)
    defaults = dict(s_max=S_MAX, gamma=GAMMA, seed=0, sampling="greedy",
                    wire_vocab=32_000)
    defaults.update(kw)
    return DeviceRuntime(slm_cfg, slm_p, policy=policy, **defaults)


def make_engine(llm_cfg, llm_p, slots: int = 2, attn_impl: str | None = None,
                verify_top_k: int = 8, cache_impl: str | None = None,
                block_size: int | None = None,
                pool_blocks: int | None = None,
                share_prefix: bool | None = None,
                swap: bool | None = None,
                host_swap_blocks: int | None = None,
                retain_prefix: bool | None = None,
                retain_blocks: int | None = None,
                host_dedupe: bool | None = None,
                paged_block_kv: int | None = None,
                kv_splits: int | None = None):
    cfg = llm_cfg if attn_impl is None else llm_cfg.replace(
        attn_impl=attn_impl)
    return CloudEngine(cfg, llm_p, max_slots=slots, s_max=S_MAX,
                       verify_top_k=verify_top_k, cache_impl=cache_impl,
                       block_size=block_size, pool_blocks=pool_blocks,
                       share_prefix=share_prefix, swap=swap,
                       host_swap_blocks=host_swap_blocks,
                       retain_prefix=retain_prefix,
                       retain_blocks=retain_blocks,
                       host_dedupe=host_dedupe,
                       paged_block_kv=paged_block_kv, kv_splits=kv_splits)


def profile_pair(dev, eng, evalset, task):
    """Offline profiling (§5): offload-all calibration pass."""
    r = SY.run_synera(dev, eng, [p for p, _ in evalset], GEN,
                      profile_mode=True)
    recs = [c for m in r.metrics for c in m.chunk_records]
    return fit_profile(recs), r


# ---------------------------------------------------------------------------
# Fig 4: hit rate vs confidence + confidence CDF
# ---------------------------------------------------------------------------

def fig4(task, slm_cfg, slm_p, llm_cfg, llm_p, n_seq: int = 8):
    rng = np.random.default_rng(3)
    confs, top1, top5 = [], [], []
    for _ in range(n_seq):
        seq, _ = task.sample_sequence(PLEN + GEN, rng)
        tk = jnp.asarray([seq], jnp.int32)
        pos = M.default_positions(1, len(seq))
        ls, _, _, _ = M.forward(slm_cfg, slm_p, tk, pos)
        ll, _, _, _ = M.forward(llm_cfg, llm_p, tk, pos)
        ps = jax.nn.softmax(ls[0].astype(jnp.float32), -1)
        conf = np.asarray(ps.max(-1))
        s_top5 = np.asarray(jax.lax.top_k(ps, 5)[1])
        l_top1 = np.asarray(jnp.argmax(ll[0], -1))
        confs += conf[:-1].tolist()
        top1 += (np.asarray(jnp.argmax(ls[0], -1)) == l_top1)[:-1].tolist()
        top5 += [(l_top1[i] in s_top5[i]) for i in range(len(seq) - 1)]
    confs = np.array(confs); top1 = np.array(top1); top5 = np.array(top5)
    bins = np.linspace(0, 1, 6)
    rows = []
    for lo, hi in zip(bins[:-1], bins[1:]):
        m = (confs >= lo) & (confs < hi if hi < 1 else confs <= hi)
        if m.sum() < 3:
            rows.append((f"{lo:.1f}-{hi:.1f}", None, None, int(m.sum())))
            continue
        rows.append((f"{lo:.1f}-{hi:.1f}", float(top1[m].mean()),
                     float(top5[m].mean()), int(m.sum())))
    frac_above_08 = float((confs > 0.8).mean())
    return {"bins": rows, "frac_conf_above_0.8": frac_above_08,
            "paper_claim": "hit rate rises with confidence; only ~16% of "
                           "tokens exceed 0.8 (Fig 4b)"}


# ---------------------------------------------------------------------------
# Fig 5a: the paper's oracle measurement protocol — rank chunks by
# FULL-CONTEXT importance (column sums over the whole SLM generation,
# including attention from future tokens) and offload the top-n%.
# ---------------------------------------------------------------------------

def fig5_oracle(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                budgets=(0.0, 0.1, 0.2, 0.3, 0.5, 0.8, 1.0),
                modes=("oracle", "random")):
    from repro.models import model as MM
    eng = make_engine(llm_cfg, llm_p)
    prompts = [p for p, _ in evalset]
    dev0 = make_device(slm_cfg, slm_p, policy=OffloadPolicy(mode="none"))
    base = SY.run_edge_centric(dev0, prompts, GEN)

    # full-context importance per chunk of each SLM-only generation
    chunk_scores = []
    for prompt, out in zip(prompts, base.outputs):
        seq = jnp.asarray([list(prompt) + [int(t) for t in out]], jnp.int32)
        _, _, imp, _ = MM.forward(
            slm_cfg.replace(attn_impl="naive"), slm_p, seq,
            MM.default_positions(1, seq.shape[1]), return_importance=True)
        gen_imp = np.asarray(imp[0])[len(prompt):]
        n_chunks = len(gen_imp) // GAMMA
        chunk_scores.append(np.array([
            gen_imp[i * GAMMA:(i + 1) * GAMMA].mean()
            for i in range(n_chunks)]))

    rng = np.random.default_rng(11)
    rows = []
    for mode in modes:
        for b in budgets:
            outs = []
            for i, prompt in enumerate(prompts):
                cs = chunk_scores[i]
                n_off = int(round(b * len(cs)))
                if mode == "oracle":
                    picked = frozenset(np.argsort(-cs)[:n_off].tolist())
                else:
                    picked = frozenset(
                        rng.choice(len(cs), size=n_off,
                                   replace=False).tolist())
                dev = make_device(slm_cfg, slm_p,
                                  policy=OffloadPolicy(mode="chunk_set",
                                                       chunk_set=picked))
                r = SY.run_synera(dev, eng, [prompt], GEN)
                outs.append(r.outputs[0])
            s = score_outputs(task, evalset, outs)
            rows.append(dict(mode=mode, budget=b, quality=s["quality"],
                             copy_acc=s["copy_acc"], nll=s["nll"]))
    return rows


# ---------------------------------------------------------------------------
# Fig 14 (and runtime budget knob): dual-metric system budget sweeps
# ---------------------------------------------------------------------------

def budget_sweep(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset, profile,
                 budgets=(0.0, 0.1, 0.2, 0.3, 0.5, 0.8, 1.0),
                 mode: str = "imp"):
    eng = make_engine(llm_cfg, llm_p)
    cost_model = CostModel()
    rows = []
    for b in budgets:
        if b <= 0:
            pol = OffloadPolicy(mode="none")
        elif b >= 1:
            pol = OffloadPolicy(mode="all")
        elif mode == "random":
            pol = OffloadPolicy(mode="random", random_rate=b)
        else:
            pol = OffloadPolicy(c_th=profile.c_th,
                                i_th=profile.i_th_for_budget(b), mode=mode)
        dev = make_device(slm_cfg, slm_p, policy=pol, alpha=profile.alpha)
        r = SY.run_synera(dev, eng, [p for p, _ in evalset], GEN,
                          cost_model=cost_model)
        s = score_outputs(task, evalset, r.outputs)
        rows.append(dict(budget=b, mode=mode, quality=s["quality"],
                         copy_acc=s["copy_acc"], nll=s["nll"],
                         tbt_ms=r.tbt_ms, cost=r.cost,
                         cloud_frac=r.cloud_fed_frac,
                         offload_rate=float(np.mean(
                             [m.offload_rate for m in r.metrics]))))
    return rows


# ---------------------------------------------------------------------------
# Table 4 + Fig 11 + Fig 12: methods comparison (+ ablations)
# ---------------------------------------------------------------------------

def methods_comparison(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                       profile, budget: float = 0.2):
    eng = make_engine(llm_cfg, llm_p)
    cost_model = CostModel()
    prompts = [p for p, _ in evalset]
    pol = OffloadPolicy(c_th=profile.c_th,
                        i_th=profile.i_th_for_budget(budget), mode="both")

    def run(name, fn):
        r = fn()
        s = score_outputs(task, evalset, r.outputs)
        return dict(method=name, quality=s["quality"], copy_acc=s["copy_acc"],
                    nll=s["nll"], tbt_ms=r.tbt_ms, cost=r.cost,
                    cloud_frac=r.cloud_fed_frac)

    dev = lambda **kw: make_device(slm_cfg, slm_p, policy=pol,
                                   alpha=profile.alpha, **kw)
    rows = [
        run("edge-centric", lambda: SY.run_edge_centric(
            make_device(slm_cfg, slm_p, policy=OffloadPolicy(mode="none")),
            prompts, GEN, cost_model=cost_model)),
        run("cloud-centric", lambda: SY.run_cloud_centric(
            eng, prompts, GEN, cost_model=cost_model)),
        run("edgefm-llm", lambda: SY.run_edgefm(
            dev(), eng, prompts, GEN, cost_model=cost_model)),
        run("hybrid", lambda: SY.run_hybrid(
            dev(), eng, prompts, GEN, cost_model=cost_model)),
        run("synera", lambda: SY.run_synera(
            dev(), eng, prompts, GEN, cost_model=cost_model)),
        # ablations (Fig 11 / Fig 16)
        run("synera-conf-only", lambda: SY.run_synera(
            make_device(slm_cfg, slm_p,
                        policy=OffloadPolicy(c_th=profile.c_th, mode="conf"),
                        alpha=profile.alpha),
            eng, prompts, GEN, cost_model=cost_model)),
        run("synera-imp-only", lambda: SY.run_synera(
            make_device(slm_cfg, slm_p,
                        policy=OffloadPolicy(
                            i_th=profile.i_th_for_budget(budget), mode="imp"),
                        alpha=profile.alpha),
            eng, prompts, GEN, cost_model=cost_model)),
        run("synera-no-pi", lambda: SY.run_synera(
            dev(use_pi=False), eng, prompts, GEN, cost_model=cost_model)),
        run("synera-no-ee", lambda: SY.run_synera(
            dev(use_early_exit=False), eng, prompts, GEN,
            cost_model=cost_model)),
    ]
    return rows


# ---------------------------------------------------------------------------
# Fig 13: bandwidth sweep
# ---------------------------------------------------------------------------

def bandwidth_sweep(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset, profile,
                    bandwidths=(0.1, 1.0, 10.0, 100.0), budget=0.35):
    eng = make_engine(llm_cfg, llm_p)
    prompts = [p for p, _ in evalset]
    pol = OffloadPolicy(c_th=profile.c_th,
                        i_th=profile.i_th_for_budget(budget), mode="both")
    rows = []
    for bw in bandwidths:
        for comp in (True, False):
            dev = make_device(slm_cfg, slm_p, policy=pol,
                              alpha=profile.alpha,
                              link=LinkModel(bandwidth_mbps=bw),
                              use_compression=comp)
            r = SY.run_synera(dev, eng, prompts, GEN)
            rows.append(dict(bandwidth_mbps=bw, compression=comp,
                             tbt_ms=r.tbt_ms,
                             uplink_kb=float(np.mean(
                                 [m.uplink_bytes for m in r.metrics]) / 1e3)))
    return rows


# ---------------------------------------------------------------------------
# Fig 15: scheduler scalability (queueing simulation over the latency model)
# ---------------------------------------------------------------------------

def scalability(budgets=(0.3, 0.6, 0.9),
                rates=(2, 5, 10, 15, 20, 25, 30, 40, 50, 60),
                sim_s: float = 20.0, seed: int = 0):
    """Poisson verification-request arrivals into the verification-aware
    scheduler's batching discipline (continuous batching over the cloud
    latency model).  Higher budgets issue more tokens per request (more
    offloaded chunks -> more uncached backlog per request), pushing the
    saturation knee to LOWER request rates — "lower budgets are more
    resilient under high throughput" (paper §6.4; note the paper's listed
    threshold<->budget pairing contradicts its own sentence — we follow
    the sentence).  Constants model a 13B verifier on A6000 (~100-400 ms
    per verification iteration, paper §3.3)."""
    lat = CloudLatencyModel(ms_base=25.0, ms_per_token=2.5,
                            ms_scheduler=0.5)
    rows = []
    rng = np.random.default_rng(seed)
    for budget in budgets:
        tokens_per_req = int(GAMMA + 1 + 12 * budget)
        for lam in rates:
            n = int(lam * sim_s)
            arrivals = np.sort(rng.uniform(0, sim_s, n)) * 1e3  # ms
            t = 0.0
            done = np.zeros(n)
            i = 0
            while i < n:
                t = max(t, arrivals[i])
                # batch everything that has arrived (continuous batching)
                j = i
                while j < n and arrivals[j] <= t:
                    j += 1
                batch = max(j - i, 1)
                iter_ms = lat.iteration_ms(batch * tokens_per_req)
                t += iter_ms
                done[i:j] = t
                i = j
            waits = done - arrivals
            rows.append(dict(budget=budget, rate=lam,
                             mean_ms=float(waits.mean()),
                             p95_ms=float(np.quantile(waits, 0.95))))
    return rows


# ---------------------------------------------------------------------------
# Fig 17: early-exit threshold sweep
# ---------------------------------------------------------------------------

def early_exit_sweep(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset, profile,
                     thresholds=(0.0, 0.3, 0.6, 0.8, 1.0), budget=0.35):
    eng = make_engine(llm_cfg, llm_p)
    prompts = [p for p, _ in evalset]
    pol = OffloadPolicy(c_th=profile.c_th,
                        i_th=profile.i_th_for_budget(budget), mode="both")
    rows = []
    for th in thresholds:
        dev = make_device(slm_cfg, slm_p, policy=pol, alpha=profile.alpha,
                          ee=EarlyExitConfig(threshold=th))
        r = SY.run_synera(dev, eng, prompts, GEN)
        s = score_outputs(task, evalset, r.outputs)
        rows.append(dict(threshold=th, quality=s["quality"],
                         tbt_ms=r.tbt_ms,
                         layers_saved=float(np.mean(
                             [m.mean_layers_saved for m in r.metrics]))))
    return rows


# ---------------------------------------------------------------------------
# Table 6 (§6.8): Synera + complementary SLM quantization
# ---------------------------------------------------------------------------

def quantization_table(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                       profile, budget: float = 0.35):
    from repro.optim.quantize import quantize_params, speedup_factor
    eng = make_engine(llm_cfg, llm_p)
    prompts = [p for p, _ in evalset]
    pol = OffloadPolicy(c_th=profile.c_th,
                        i_th=profile.i_th_for_budget(budget), mode="both")
    rows = []
    for label, bits in (("fp32", 0), ("int8", 8), ("int4", 4)):
        params = quantize_params(slm_p, bits) if bits else slm_p
        lat = DeviceLatencyModel(
            ms_per_token=DeviceLatencyModel().ms_per_token
            / speedup_factor(bits) if bits else
            DeviceLatencyModel().ms_per_token)
        dev_e = make_device(slm_cfg, params, latency=lat,
                            policy=OffloadPolicy(mode="none"))
        r_e = SY.run_edge_centric(dev_e, prompts, GEN)
        s_e = score_outputs(task, evalset, r_e.outputs)
        dev_s = make_device(slm_cfg, params, latency=lat, policy=pol,
                            alpha=profile.alpha)
        r_s = SY.run_synera(dev_s, eng, prompts, GEN)
        s_s = score_outputs(task, evalset, r_s.outputs)
        rows.append(dict(
            quant=label,
            edge_quality=s_e["quality"], synera_quality=s_s["quality"],
            rel_gain=s_s["quality"] / max(s_e["quality"], 1e-9),
            edge_tbt=r_e.tbt_ms, synera_tbt=r_s.tbt_ms))
    return rows


# ---------------------------------------------------------------------------
# Fig 18: scheduler overhead + §6.5 PI hit rate + Table 5 energy
# ---------------------------------------------------------------------------

def overhead_and_hits(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset, profile,
                      budgets=(0.2, 0.5, 0.8)):
    eng = make_engine(llm_cfg, llm_p)
    prompts = [p for p, _ in evalset]
    lat = CloudLatencyModel()
    rows = []
    for b in budgets:
        pol = OffloadPolicy(c_th=profile.c_th,
                            i_th=profile.i_th_for_budget(b), mode="both")
        dev = make_device(slm_cfg, slm_p, policy=pol, alpha=profile.alpha)
        r = SY.run_synera(dev, eng, prompts, GEN)
        pi_att = sum(m.pi_attempts for m in r.metrics)
        pi_hit = sum(m.pi_position_hits for m in r.metrics)
        pi_adopt = sum(m.pi_adopted for m in r.metrics)
        # scheduler overhead: fixed scheduling cost vs per-iteration compute
        fed = sum(m.n_cloud_fed_tokens for m in r.metrics)
        iters = max(1, fed // 32 + 1)
        sched_ms = iters * lat.ms_scheduler
        compute_ms = fed * lat.ms_per_token + iters * lat.ms_base
        energy = float(np.mean([m.timeline.energy_j /
                                max(len(m.tokens), 1) for m in r.metrics]))
        rows.append(dict(budget=b,
                         pi_hit_rate=pi_hit / max(pi_att, 1),
                         pi_adopt_rate=pi_adopt / max(pi_att, 1),
                         sched_overhead=sched_ms / max(compute_ms + sched_ms,
                                                       1e-9),
                         energy_j_per_token=energy))
    return rows
