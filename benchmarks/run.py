"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time per
generated token or per kernel call where applicable; derived = the
headline metric of that artifact) and writes the full records to
results/benchmarks.json.

Usage: PYTHONPATH=src:. python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np


def main() -> None:
    fast = "--fast" in sys.argv
    t_all = time.time()
    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    n_eval = 3 if fast else 6
    evalset = PC.eval_set(task, n_eval)
    results = {}

    def record(name, payload, us_per_call, derived):
        results[name] = payload
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)

    # ---- offline profiling (§5) -----------------------------------------
    t0 = time.time()
    dev0 = PC.make_device(slm_cfg, slm_p)
    eng0 = PC.make_engine(llm_cfg, llm_p)
    profile, prof_run = PC.profile_pair(dev0, eng0, evalset, task)
    n_tok = sum(len(m.tokens) for m in prof_run.metrics)
    record("profiling_sec5", dict(c_th=profile.c_th, alpha=profile.alpha,
                                  gamma=profile.gamma),
           (time.time() - t0) / max(n_tok, 1) * 1e6,
           f"c_th={profile.c_th:.3f};alpha={profile.alpha:.3f}")

    # ---- Fig 4 ----------------------------------------------------------
    t0 = time.time()
    f4 = PC.fig4(task, slm_cfg, slm_p, llm_cfg, llm_p,
                 n_seq=4 if fast else 8)
    record("fig4_confidence", f4, (time.time() - t0) * 1e6 / 8,
           f"frac_conf>0.8={f4['frac_conf_above_0.8']:.3f}")

    # ---- Fig 5a: oracle importance vs random (the paper's protocol) -----
    budgets = (0.0, 0.2, 0.5, 1.0) if fast else (0.0, 0.1, 0.2, 0.3, 0.5,
                                                 0.8, 1.0)
    t0 = time.time()
    f5 = PC.fig5_oracle(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                        budgets=budgets)
    q_imp = {r["budget"]: r["quality"] for r in f5 if r["mode"] == "oracle"}
    q_rnd = {r["budget"]: r["quality"] for r in f5 if r["mode"] == "random"}
    n_tok = n_eval * PC.GEN * len(budgets) * 2
    record("fig5_oracle", f5, (time.time() - t0) / n_tok * 1e6,
           f"q@0.2(imp)={q_imp.get(0.2, 0):.3f};q@0.2(rand)={q_rnd.get(0.2, 0):.3f}")

    # ---- Fig 14: runtime dual-metric budget sweep ------------------------
    t0 = time.time()
    f14 = PC.budget_sweep(task, slm_cfg, slm_p, llm_cfg, llm_p,
                          evalset, profile, budgets=budgets, mode="both")
    k02 = next((r for r in f14 if abs(r["budget"] - 0.2) < 1e-9), f14[0])
    record("fig14_tradeoff", f14, (time.time() - t0) / n_tok * 1e6,
           f"q@0.2={k02['quality']:.3f};cost@0.2={k02['cost']:.2f};"
           f"tbt@0.2={k02['tbt_ms']:.0f}ms")

    # ---- Table 4 / Fig 11 / Fig 12 --------------------------------------
    t0 = time.time()
    methods = PC.methods_comparison(task, slm_cfg, slm_p, llm_cfg, llm_p,
                                    evalset, profile)
    by = {r["method"]: r for r in methods}
    n_tok = n_eval * PC.GEN * len(methods)
    gain = by["synera"]["quality"] / max(by["edge-centric"]["quality"], 1e-9)
    cost_cut = 1 - by["synera"]["cost"] / max(by["cloud-centric"]["cost"],
                                              1e-9)
    record("table4_fig11_fig12_methods", methods,
           (time.time() - t0) / n_tok * 1e6,
           f"quality_gain_vs_edge={gain:.2f}x;cost_cut_vs_cloud={cost_cut:.2%}")

    # ---- Fig 13 ----------------------------------------------------------
    t0 = time.time()
    bw = PC.bandwidth_sweep(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                            profile,
                            bandwidths=(0.1, 10.0) if fast
                            else (0.1, 1.0, 10.0, 100.0))
    lo_c = [r for r in bw if r["bandwidth_mbps"] == 0.1 and r["compression"]]
    lo_n = [r for r in bw if r["bandwidth_mbps"] == 0.1 and not r["compression"]]
    record("fig13_bandwidth", bw, (time.time() - t0) * 1e3,
           f"tbt@0.1Mbps comp={lo_c[0]['tbt_ms']:.0f}ms "
           f"nocomp={lo_n[0]['tbt_ms']:.0f}ms")

    # ---- Fig 15 ----------------------------------------------------------
    t0 = time.time()
    sc = PC.scalability()
    knees = {}
    for b in (0.3, 0.6, 0.9):
        rs = [r for r in sc if r["budget"] == b]
        base = rs[0]["mean_ms"]
        knee = next((r["rate"] for r in rs if r["mean_ms"] > 5 * base),
                    rs[-1]["rate"])
        knees[b] = knee
    record("fig15_scalability", sc, (time.time() - t0) * 1e6 / len(sc),
           f"saturation_rates={knees}")

    # ---- Fig 17 ----------------------------------------------------------
    t0 = time.time()
    ths = (0.0, 0.8, 1.0) if fast else (0.0, 0.3, 0.6, 0.8, 1.0)
    ee = PC.early_exit_sweep(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                             profile, thresholds=ths)
    q1 = next(r for r in ee if r["threshold"] == max(ths))
    q08 = next(r for r in ee if abs(r["threshold"] - 0.8) < 1e-9)
    record("fig17_early_exit", ee,
           (time.time() - t0) / (n_eval * PC.GEN * len(ee)) * 1e6,
           f"q@0.8={q08['quality']:.3f} vs q@1.0={q1['quality']:.3f};"
           f"layers_saved@0.8={q08['layers_saved']:.2%}")

    # ---- Fig 18 + §6.5 ----------------------------------------------------
    t0 = time.time()
    oh = PC.overhead_and_hits(task, slm_cfg, slm_p, llm_cfg, llm_p, evalset,
                              profile)
    record("fig18_sec65_overhead_pihits", oh, (time.time() - t0) * 1e3,
           f"pi_hit@0.5={oh[1]['pi_hit_rate']:.2f};"
           f"sched_overhead@0.8={oh[2]['sched_overhead']:.2%}")

    # ---- Table 6 (§6.8): quantization complementarity --------------------
    t0 = time.time()
    tq = PC.quantization_table(task, slm_cfg, slm_p, llm_cfg, llm_p,
                               evalset, profile)
    gains = {r["quant"]: r["rel_gain"] for r in tq}
    record("table6_quantization", tq,
           (time.time() - t0) / (n_eval * PC.GEN * 6) * 1e6,
           f"rel_gain fp32={gains.get('fp32', 0):.2f} "
           f"int8={gains.get('int8', 0):.2f} int4={gains.get('int4', 0):.2f}")

    # ---- kernel microbench ------------------------------------------------
    from benchmarks.kernel_bench import kernel_micro
    for row in kernel_micro():
        record(f"kernel_{row['name']}", row, row["us_per_call"],
               f"max_err={row['max_err']:.1e}")

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# total {time.time()-t_all:.1f}s -> results/benchmarks.json",
          flush=True)


if __name__ == "__main__":
    main()
