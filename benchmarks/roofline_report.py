"""Assemble the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
results/dryrun/*.json records produced by repro.launch.dryrun.

  PYTHONPATH=src:. python -m benchmarks.roofline_report [--tag base]
"""
from __future__ import annotations

import argparse
import glob
import json


SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "verify_32k"]


def load(tag: str = "base", out_dir: str = "results/dryrun"):
    recs = {}
    for p in glob.glob(f"{out_dir}/*.json"):
        r = json.load(open(p))
        if r.get("tag", "") != tag:
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        recs[key] = r
    return recs


def fmt_e(x):
    return f"{x:.2e}"


def roofline_table(recs, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | useful-FLOP ratio |",
        "|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or not r.get("ok"):
            continue
        ro = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {fmt_e(ro['t_compute'])} | "
            f"{fmt_e(ro['t_memory'])} | {fmt_e(ro['t_collective'])} | "
            f"**{ro['bottleneck']}** | {ro['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compile (s) | FLOPs/dev | bytes/dev | "
        "coll bytes/dev | temp GiB/dev | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if not r.get("ok"):
            lines.append(f"| {arch} | {shape} | {m} | - | - | - | - | - | "
                         f"FAIL: {r.get('error', '?')[:60]} |")
            continue
        mem = r["memory"]["temp_bytes"] / 2 ** 30
        lines.append(
            f"| {arch} | {shape} | {m} | {r['compile_s']:.1f} | "
            f"{fmt_e(r['flops_per_dev'])} | {fmt_e(r['bytes_per_dev'])} | "
            f"{fmt_e(r['collective_bytes_per_dev'])} | {mem:.2f} | OK |")
    return "\n".join(lines)


def summarize(recs):
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    bottl = {}
    for r in recs.values():
        if r.get("ok"):
            b = r["roofline"]["bottleneck"]
            bottl[b] = bottl.get(b, 0) + 1
    return n_ok, len(recs), bottl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="base")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.tag, args.out_dir)
    n_ok, n, bottl = summarize(recs)
    print(f"## records: {n_ok}/{n} OK; bottleneck histogram: {bottl}\n")
    print("### Dry-run (both meshes)\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))


if __name__ == "__main__":
    main()
