"""Train (once) and cache the tiny SLM/LLM pair used by the paper-claim
benchmarks.  Checkpoints land in results/ckpt/; reruns load from disk.
"""
from __future__ import annotations

import os

import numpy as np

import jax

from repro.checkpoint import io as ckpt
from repro.configs.synera_pair import tiny_pair
from repro.data.synthetic import SyntheticTask, TaskSpec
from repro.launch.train import train
from repro.models import model as M

CKPT_DIR = "results/ckpt"
VOCAB = 64
STEPS_SLM = 250
STEPS_LLM = 400


def get_pair(steps_slm: int = STEPS_SLM, steps_llm: int = STEPS_LLM,
             force: bool = False):
    """Returns (slm_cfg, slm_params, llm_cfg, llm_params, task)."""
    slm_cfg, llm_cfg = tiny_pair(vocab=VOCAB)
    task = SyntheticTask(TaskSpec(vocab=VOCAB))
    os.makedirs(CKPT_DIR, exist_ok=True)
    out = []
    corpus = None
    for cfg, steps in ((slm_cfg, steps_slm), (llm_cfg, steps_llm)):
        path = f"{CKPT_DIR}/{cfg.name}.npz"
        like = jax.eval_shape(lambda k, c=cfg: M.init_params(c, k),
                              jax.ShapeDtypeStruct((2,), np.uint32))
        if os.path.exists(path) and not force:
            params = ckpt.load(path, like)
            print(f"loaded {cfg.name} from {path}")
        else:
            if corpus is None:
                corpus, _ = task.corpus(n_sequences=64, length=2048, seed=0)
            print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M) "
                  f"for {steps} steps...")
            params, _ = train(cfg, steps=steps, corpus=corpus,
                              log_every=100, ckpt_path=path)
        out.append(params)
    return slm_cfg, out[0], llm_cfg, out[1], task


if __name__ == "__main__":
    get_pair(force="--force" in __import__("sys").argv)
