"""Validate a Perfetto/Chrome trace-event JSON file (serve.py
--trace-out) and the stall-attribution invariant.

Structural checks (Chrome trace-event format, JSON object flavor):

* top level is an object with a ``traceEvents`` list;
* every event has ``ph``/``pid``/``tid``/``name`` with sane types and a
  non-negative ``ts`` (metadata events excepted);
* complete events (``ph: X``) carry ``dur >= 0``;
* nestable async events (``b``/``e``) balance per ``(pid, cat, id)``
  with no ``e`` before its ``b`` and no track left open;
* instants (``i``/``n``) carry a valid scope.

Semantic check: every completed stream's closing ``e`` event carries
``args.buckets`` (the exclusive stall decomposition) and ``args.wall_ms``;
the buckets must sum to the wall time within ``1e-6 * max(1, wall)`` —
the tracer's core invariant (docs/observability.md).

  python tools/check_trace.py trace.json [--min-streams N]

Exit 0 when valid; exit 1 with one line per problem otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys

VALID_PH = {"B", "E", "X", "i", "I", "b", "e", "n", "M", "C", "s", "t",
            "f"}


def check_events(events) -> tuple[list[str], dict]:
    """Return (errors, summary) for a traceEvents list."""
    errors = []
    open_async: dict = {}      # (pid, cat, id) -> depth
    n_streams = n_checked = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in VALID_PH:
            errors.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing/non-string name")
        for fld in ("pid", "tid"):
            if not isinstance(ev.get(fld), int):
                errors.append(f"{where}: missing/non-int {fld}")
        if ph == "M":
            continue               # metadata carries no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event with bad dur {dur!r}")
        elif ph in ("i", "I"):
            if ev.get("s", "t") not in ("g", "p", "t"):
                errors.append(f"{where}: instant with bad scope "
                              f"{ev.get('s')!r}")
        elif ph in ("b", "e", "n"):
            if "id" not in ev:
                errors.append(f"{where}: async event without id")
                continue
            key = (ev.get("pid"), ev.get("cat"), str(ev["id"]))
            if ph == "b":
                open_async[key] = open_async.get(key, 0) + 1
            elif ph == "e":
                depth = open_async.get(key, 0)
                if depth <= 0:
                    errors.append(f"{where}: 'e' with no open 'b' for "
                                  f"{key}")
                else:
                    open_async[key] = depth - 1
                # the outermost close of a stream track carries the
                # stall decomposition
                args = ev.get("args") or {}
                if ev.get("cat") == "stream" and "buckets" in args:
                    n_checked += 1
                    wall = float(args.get("wall_ms", 0.0))
                    total = sum(float(v)
                                for v in args["buckets"].values())
                    tol = 1e-6 * max(1.0, abs(wall))
                    if abs(total - wall) > tol:
                        errors.append(
                            f"{where}: stream {ev.get('name')}: buckets "
                            f"sum {total!r} != wall {wall!r} "
                            f"(|diff|={abs(total - wall):.3e} > {tol:.0e})")
    for key, depth in open_async.items():
        if depth != 0:
            errors.append(f"unbalanced async track {key}: "
                          f"{depth} open 'b' events at EOF")
    for ev in events:
        if (isinstance(ev, dict) and ev.get("ph") == "b"
                and ev.get("cat") == "stream"
                and str(ev.get("name", "")).startswith(("stream-",
                                                        "degraded-"))):
            n_streams += 1
    return errors, {"events": len(events), "streams": n_streams,
                    "buckets_checked": n_checked}


def check_file(path: str, min_streams: int = 0) -> tuple[list[str], dict]:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return (["top level must be an object with a 'traceEvents' "
                 "list"], {})
    errors, summary = check_events(doc["traceEvents"])
    if summary.get("streams", 0) < min_streams:
        errors.append(f"expected >= {min_streams} stream tracks, found "
                      f"{summary.get('streams', 0)}")
    if min_streams > 0 and summary.get("buckets_checked", 0) == 0:
        errors.append("no stream carried a bucket decomposition "
                      "(args.buckets on its closing event)")
    return errors, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace JSON file (serve.py --trace-out)")
    ap.add_argument("--min-streams", type=int, default=1,
                    help="fail unless at least N per-stream async "
                         "tracks are present (0 disables)")
    args = ap.parse_args()
    try:
        errors, summary = check_file(args.trace, args.min_streams)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{args.trace}: unreadable: {e}", file=sys.stderr)
        return 1
    for e in errors:
        print(f"{args.trace}: {e}", file=sys.stderr)
    status = "FAIL" if errors else "ok"
    print(f"{args.trace}: {status} ({summary.get('events', 0)} events, "
          f"{summary.get('streams', 0)} streams, "
          f"{summary.get('buckets_checked', 0)} bucket sums checked, "
          f"{len(errors)} errors)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
