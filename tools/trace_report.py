"""Print the stall-attribution breakdown of a trace JSON file
(serve.py --trace-out / gateway /v1/traces).

Reads the per-stream bucket decomposition the tracer attaches to each
stream's closing event (``args.buckets`` + ``args.wall_ms``, see
docs/observability.md) and prints one row per completed stream plus an
aggregate row with per-bucket shares of total wall time.

  python tools/trace_report.py trace.json [--top N]
"""
from __future__ import annotations

import argparse
import json
import sys

BUCKETS = ("device", "cloud", "link", "queue", "batch_wait", "swap",
           "preempted", "other")


def stream_rows(doc: dict) -> list[dict]:
    """Extract {name, wall_ms, tokens, <bucket>...} per ended stream."""
    rows = []
    for ev in doc.get("traceEvents", []):
        if (not isinstance(ev, dict) or ev.get("ph") != "e"
                or ev.get("cat") != "stream"):
            continue
        args = ev.get("args") or {}
        if "buckets" not in args:
            continue
        row = {"name": ev.get("name", "?"),
               "wall_ms": float(args.get("wall_ms", 0.0)),
               "tokens": int(args.get("tokens", 0))}
        for b in BUCKETS:
            row[b] = float(args["buckets"].get(b, 0.0))
        rows.append(row)
    return rows


def render(rows: list[dict], top: int = 0) -> str:
    if not rows:
        return "no completed streams with bucket decompositions found\n"
    hdr = (["stream", "wall_ms", "tok"] + list(BUCKETS))
    widths = [max(len(h), 10) for h in hdr]
    widths[0] = max(len(r["name"]) for r in rows + [{"name": "TOTAL"}])
    widths[0] = max(widths[0], len("stream"))
    lines = ["  ".join(h.rjust(w) for h, w in zip(hdr, widths))]
    body = sorted(rows, key=lambda r: -r["wall_ms"])
    if top:
        body = body[:top]
    for r in body:
        cells = [r["name"].rjust(widths[0]),
                 f"{r['wall_ms']:.1f}".rjust(widths[1]),
                 f"{r['tokens']}".rjust(widths[2])]
        cells += [f"{r[b]:.1f}".rjust(w)
                  for b, w in zip(BUCKETS, widths[3:])]
        lines.append("  ".join(cells))
    total_wall = sum(r["wall_ms"] for r in rows)
    totals = {b: sum(r[b] for r in rows) for b in BUCKETS}
    cells = ["TOTAL".rjust(widths[0]),
             f"{total_wall:.1f}".rjust(widths[1]),
             f"{sum(r['tokens'] for r in rows)}".rjust(widths[2])]
    cells += [f"{totals[b]:.1f}".rjust(w)
              for b, w in zip(BUCKETS, widths[3:])]
    lines.append("  ".join(cells))
    if total_wall > 0:
        cells = ["share".rjust(widths[0]), "".rjust(widths[1]),
                 "".rjust(widths[2])]
        cells += [f"{100.0 * totals[b] / total_wall:.1f}%".rjust(w)
                  for b, w in zip(BUCKETS, widths[3:])]
        lines.append("  ".join(cells))
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace JSON file (serve.py --trace-out)")
    ap.add_argument("--top", type=int, default=0,
                    help="print only the N slowest streams (0 = all)")
    args = ap.parse_args()
    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{args.trace}: unreadable: {e}", file=sys.stderr)
        return 1
    rows = stream_rows(doc)
    sys.stdout.write(render(rows, top=args.top))
    return 0 if rows else 1


if __name__ == "__main__":
    sys.exit(main())
