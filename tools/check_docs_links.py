"""Fail CI on dead relative links in README.md and docs/.

Scans markdown links and images (``[text](target)``), skips absolute
URLs (http/https/mailto) and pure in-page anchors (``#...``), strips
anchors from file targets, and verifies every remaining path exists
relative to the file that references it.

  python tools/check_docs_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def doc_files(root: Path):
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files += sorted((root / "docs").glob("**/*.md"))
    return files


def check(root: Path) -> list[str]:
    errors = []
    for md in doc_files(root):
        text = md.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                line = text[:m.start()].count("\n") + 1
                errors.append(f"{md.relative_to(root)}:{line}: "
                              f"dead link -> {target}")
    return errors


def main():
    root = Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    files = doc_files(root)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    errors = check(root)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} dead links)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
