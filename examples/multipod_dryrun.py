"""Lower + compile one (architecture x input shape) on the production
mesh and print its roofline terms — the per-combination view of the full
sweep in repro.launch.dryrun.

  PYTHONPATH=src python examples/multipod_dryrun.py --arch glm4-9b \
      --shape decode_32k [--multi-pod]
"""
# NOTE: must run as a fresh process — jax locks the device count on init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_one
    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  out_dir="results/dryrun_examples")
    if not rec["ok"]:
        raise SystemExit(rec["error"])
    r = rec["roofline"]
    print(f"{args.arch} x {args.shape} on {rec['mesh']} "
          f"({rec['chips']} chips):")
    print(f"  compile: {rec['compile_s']:.1f}s")
    print(f"  t_compute    = {r['t_compute']:.3e} s")
    print(f"  t_memory     = {r['t_memory']:.3e} s")
    print(f"  t_collective = {r['t_collective']:.3e} s")
    print(f"  bottleneck   = {r['bottleneck']}")
    print(f"  useful-FLOP ratio = {r['useful_flops_ratio']:.2f}")
    mem = rec["memory"]
    print(f"  per-device bytes: args {mem['argument_bytes']/2**30:.2f} GiB, "
          f"temps {mem['temp_bytes']/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
