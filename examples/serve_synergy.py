"""End-to-end driver: serve a trained SLM/LLM pair with batched requests
across all five serving modes and print the paper's headline comparison
(quality x latency x cloud cost).

Trains the pair on first run (cached in results/ckpt/), then serves
batched requests through the verification-aware scheduler.

  PYTHONPATH=src:. python examples/serve_synergy.py [--budget 0.35]
"""
import argparse

import numpy as np

from benchmarks import paper_claims as PC
from benchmarks.prepare import get_pair
from repro.core.offload import OffloadPolicy
from repro.serving import synergy as SY
from repro.serving.link import CostModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=0.35)
    ap.add_argument("--n", type=int, default=6, help="#requests")
    ap.add_argument("--max-new", type=int, default=40)
    args = ap.parse_args()

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    evalset = PC.eval_set(task, args.n)
    prompts = [p for p, _ in evalset]

    # offline profiling (Synera §5)
    dev0 = PC.make_device(slm_cfg, slm_p)
    eng = PC.make_engine(llm_cfg, llm_p, slots=4)
    profile, _ = PC.profile_pair(dev0, eng, evalset, task)
    print(f"profile: c_th={profile.c_th:.3f} alpha={profile.alpha:.3f} "
          f"gamma={profile.gamma}")

    pol = OffloadPolicy(c_th=profile.c_th,
                        i_th=profile.i_th_for_budget(args.budget),
                        mode="both")
    cost_model = CostModel()

    dev_syn = PC.make_device(slm_cfg, slm_p, policy=pol, alpha=profile.alpha)
    runs = {
        "edge-centric": SY.run_edge_centric(
            PC.make_device(slm_cfg, slm_p,
                           policy=OffloadPolicy(mode="none")),
            prompts, args.max_new, cost_model=cost_model),
        "cloud-centric": SY.run_cloud_centric(
            eng, prompts, args.max_new, cost_model=cost_model),
        "synera": SY.run_synera(
            dev_syn, eng, prompts, args.max_new, cost_model=cost_model),
        # multi-tenant: all streams share the engine through the
        # SyneraServer event loop (identical greedy outputs, packed
        # verify iterations)
        "synera-batched": SY.run_synera(
            dev_syn, eng, prompts, args.max_new, cost_model=cost_model,
            concurrency=min(len(prompts), 4)),
    }

    print(f"\n{'method':15s} {'quality':>8s} {'copy_acc':>9s} "
          f"{'TBT(ms)':>8s} {'cost':>7s} {'cloud%':>7s}")
    for name, r in runs.items():
        s = PC.score_outputs(task, evalset, r.outputs)
        print(f"{name:15s} {s['quality']:8.3f} {s['copy_acc']:9.2%} "
              f"{r.tbt_ms:8.1f} {r.cost:7.2f} {r.cloud_fed_frac:7.1%}")

    m = runs["synera"].metrics[0]
    print(f"\nsynera detail: PI hits {m.pi_position_hits}/{m.pi_attempts}, "
          f"layers saved {m.mean_layers_saved:.1%}, "
          f"stall {m.timeline.stall_ms:.0f} ms of {m.timeline.t_ms:.0f} ms")
    st = runs["synera-batched"].extras["scheduler"]
    print(f"batched serving: verify occupancy "
          f"{st['mean_verify_occupancy']:.2f} slots/iter "
          f"(max {st['max_verify_occupancy']}), "
          f"{st['mean_packed_tokens']:.1f} packed tokens/iter, "
          f"{st['iterations']} iterations")


if __name__ == "__main__":
    main()
