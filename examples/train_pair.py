"""Train the SLM/LLM pair on the synthetic corpus (the framework's
training substrate: data pipeline -> AdamW -> checkpointing).

  PYTHONPATH=src python examples/train_pair.py --steps 200
"""
import argparse

from repro.configs.synera_pair import tiny_pair
from repro.data.synthetic import SyntheticTask, TaskSpec
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--vocab", type=int, default=64)
    args = ap.parse_args()

    slm_cfg, llm_cfg = tiny_pair(vocab=args.vocab)
    task = SyntheticTask(TaskSpec(vocab=args.vocab))
    corpus, _ = task.corpus(n_sequences=64, length=2048, seed=0)

    for cfg in (slm_cfg, llm_cfg):
        print(f"== {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
              f"({cfg.param_count()/1e6:.1f}M params)")
        _, losses = train(cfg, steps=args.steps, corpus=corpus,
                          log_every=50,
                          ckpt_path=f"results/ckpt/{cfg.name}.npz")
        print(f"   loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
