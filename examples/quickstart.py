"""Quickstart: device-cloud synergistic serving in ~40 lines.

Builds a tiny SLM (device) + LLM (cloud) pair, wires them through the
verification-aware scheduler, and generates with selective token-level
offloading.  Runs in <1 min on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.synera_pair import tiny_pair
from repro.core.offload import OffloadPolicy
from repro.models import model as M
from repro.serving.device import DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving import synergy as SY


def main():
    # 1. models: on-device SLM + cloud LLM (random weights for the demo;
    #    see examples/serve_synergy.py for the trained pair)
    slm_cfg, llm_cfg = tiny_pair(vocab=64)
    slm_params = M.init_params(slm_cfg, jax.random.PRNGKey(0))
    llm_params = M.init_params(llm_cfg, jax.random.PRNGKey(1))

    # 2. device runtime: draft chunks of gamma tokens, offload the
    #    quality-critical ones (confidence + importance dispatch)
    # (i_th is normally fitted by offline profiling — see
    # examples/serve_synergy.py; hand-set here for the untrained demo)
    device = DeviceRuntime(
        slm_cfg, slm_params, gamma=4, s_max=256,
        policy=OffloadPolicy(c_th=0.8, i_th=0.04, mode="both"))

    # 3. cloud runtime: slot-based continuous batching engine
    engine = CloudEngine(llm_cfg, llm_params, max_slots=4, s_max=256)

    # 4. generate
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [8, 7, 6, 5, 4, 3, 2, 1]]
    result = SY.run_synera(device, engine, prompts, max_new=24)

    for i, out in enumerate(result.outputs):
        m = result.metrics[i]
        print(f"prompt {i}: {out}")
        print(f"  offloaded {m.n_offloaded}/{m.n_chunks} chunks, "
              f"acceptance {m.acceptance_rate:.2f}, "
              f"TBT {m.tbt_ms:.1f} ms (modeled), "
              f"uplink {m.uplink_bytes} B")
    print(f"cloud token fraction: {result.cloud_token_frac:.2f}")


if __name__ == "__main__":
    main()
