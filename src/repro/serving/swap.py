"""Host-memory KV swap tier + preemption policy layer (ROADMAP:
swap-based preemption, SLO-aware victim selection).

Before this module the paged pool had one relief valve under pressure:
recompute-eviction — the victim's cloud frontier rewinds to zero and
its whole accepted prefix re-feeds as a from-scratch partial prefill,
burning verifier FLOPs and stalling the device pipeline the paper's
stall-free design is meant to avoid.  The swap tier adds a second
disposition: move the victim's pool blocks to a host-side block store
(one jitted, donated gather per stream — ``models/model.swap_out_blocks``
over every layer stack, like ``copy_cache_blocks``) and scatter them
back into freshly allocated blocks when pressure clears
(``swap_in_blocks``).  Restored blocks are bit-identical, so token
streams are unchanged; only the modeled clock pays the D2H+H2D round
trip through ``CloudLatencyModel.host_link_gbps``.

Two policy decisions live here, both consumed by the scheduler:

* **Victim selection** (:func:`pick_victim`): ``youngest`` (the
  pre-swap behaviour and the identity oracle), ``most-blocks`` (free
  the most memory per eviction), ``slo-aware`` (evict the stream with
  the most remaining TTFT/deadline slack; streams without an SLO are
  preferred victims).
* **Disposition** (swap vs recompute, decided by the scheduler per
  victim): swap when the modeled round trip
  (``latency.swap_roundtrip_ms`` on the victim's measured block bytes)
  undercuts the modeled re-prefill (``latency.refeed_ms`` on its
  accepted frontier), or when the victim cannot restart at all
  (requests without ``seq``).

Prefix-sharing interaction: blocks mapped by a sibling (refcount > 1)
never leave the pool — the victim only *drops its reference* and
records how many leading blocks it rode on.  At swap-in those blocks
are re-adopted from the prefix index (ref++ again) when the share still
exists; if the sibling has meanwhile died and taken the blocks with it,
the swap-in degrades to recompute-eviction for that stream (the host
payload alone cannot rebuild the missing prefix KV).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.serving.engine import BlockPoolExhausted, _call_donated

PREEMPT_POLICIES = ("youngest", "most-blocks", "slo-aware")


@dataclass(frozen=True)
class StreamSLO:
    """Per-stream latency budgets, relative to the stream's arrival on
    the shared clock.  ``ttft_ms`` bounds time to the first verified
    emission, ``deadline_ms`` time to stream completion; ``inf`` means
    unconstrained (the stream is a preferred eviction victim under the
    ``slo-aware`` policy)."""
    ttft_ms: float = float("inf")
    deadline_ms: float = float("inf")


def pick_victim(policy: str, cands: list[int], sched) -> int:
    """Choose the eviction victim among candidate slots (all hold pool
    blocks, none is the protected oldest holder).  Ties break toward
    the youngest stream, which keeps ``youngest`` the exact pre-policy
    behaviour."""
    age = sched.slot_age
    if policy == "youngest":
        return max(cands, key=lambda s: age[s])
    if policy == "most-blocks":
        a = sched.engine.allocator

        def freeable(s):
            # only sole-owned blocks actually return to the pool;
            # ref>1 shared-lead blocks merely drop a reference
            return sum(1 for j in range(int(a.n_blocks_of[s]))
                       if int(a.ref[int(a.table[s, j])]) == 1)

        return max(cands, key=lambda s: (freeable(s), age[s]))
    if policy == "slo-aware":
        now = sched.clock.now_ms
        return max(cands, key=lambda s: (sched.slot_slack_ms(s, now),
                                         age[s]))
    raise ValueError(
        f"unknown preemption policy {policy!r}; have {PREEMPT_POLICIES}")


@dataclass
class SwappedStream:
    """Host-side metadata for one swapped-out stream: the block-table
    shape it had (total blocks, how many leading ones were shared), the
    cloud frontier to restore, and the gathered k/v/pos payload."""
    slot: int
    frontier: int                  # cloud_len at swap-out
    n_blocks: int                  # blocks the slot held (incl. shared lead)
    shared_lead: int               # leading blocks left in-pool (ref dropped)
    n_swap: int                    # blocks resident on the host
    nbytes: int                    # modeled payload bytes (n_swap x block)
    probe: tuple                   # tokens re-matching the shared lead
    payload: object = None         # host numpy pytree (k/v/pos per stack)


class HostSwapManager:
    """Host-side block store for swapped-out streams.

    Mechanism only: the scheduler decides *who* is evicted and *whether*
    swap beats recompute; this class executes the transfers (jitted,
    donated, one dispatch across all layer stacks per direction, fixed
    ``(max_bps,)`` plans so jit specialization is O(1)) and keeps the
    per-stream metadata.  ``max_host_blocks`` caps the store (0 =
    unbounded); a victim that does not fit falls back to recompute.
    """

    def __init__(self, engine, max_host_blocks: int = 0):
        self.engine = engine
        self.max_host_blocks = int(max_host_blocks)
        self._streams: dict[int, SwappedStream] = {}   # slot -> stream, FIFO
        self._gather = jax.jit(M.swap_out_blocks, donate_argnums=0)
        self._scatter = jax.jit(M.swap_in_blocks, donate_argnums=0)
        # telemetry (cumulative; pool_stats / ServerStats)
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        self.expired_shares = 0

    # -- introspection --------------------------------------------------
    @property
    def swapped_blocks(self) -> int:
        """Blocks currently resident in the host store."""
        return sum(st.n_swap for st in self._streams.values())

    @property
    def swapped_slots(self) -> list[int]:
        """Swapped-out slots in swap-out (FIFO) order."""
        return list(self._streams)

    def holds(self, slot: int) -> bool:
        return slot in self._streams

    def blocks_needed(self, slot: int) -> int:
        """Fresh pool blocks a swap-in of ``slot`` must allocate (the
        shared lead re-adopts from the index at no block cost)."""
        return self._streams[slot].n_swap

    def plan(self, slot: int) -> tuple[int, int, int] | None:
        """Whether ``slot`` can swap out, and at what cost: returns
        ``(shared_lead, n_swap, nbytes)`` or None when swap is not
        possible — no blocks, already swapped, an interior (non-leading)
        shared block (only leading prompt blocks can re-adopt), or the
        host store is full."""
        a = self.engine.allocator
        n = int(a.n_blocks_of[slot])
        if n == 0 or slot in self._streams:
            return None
        bids = [int(a.table[slot, j]) for j in range(n)]
        shared = [j for j, b in enumerate(bids) if int(a.ref[b]) > 1]
        if shared != list(range(len(shared))):
            return None
        n_swap = n - len(shared)
        if self.max_host_blocks and \
                self.swapped_blocks + n_swap > self.max_host_blocks:
            return None
        return len(shared), n_swap, n_swap * self.engine.block_bytes()

    # -- transfers ------------------------------------------------------
    def swap_out(self, slot: int, tokens, frontier: int) -> int | None:
        """Evict ``slot`` to the host store: gather its unshared blocks
        (k/v/pos across every layer stack, one donated dispatch that
        also invalidates their pool positions), drop its reference on
        shared-lead blocks, and return all its pool blocks to the free
        list.  ``tokens`` must cover the shared lead (the stream's
        prompt) so the lead can be re-matched at swap-in.  Returns the
        modeled bytes moved, or None when the swap is not possible (the
        caller falls back to recompute-eviction)."""
        p = self.plan(slot)
        if p is None:
            return None
        lead, n_swap, nbytes = p
        a = self.engine.allocator
        bs = a.block_size
        if lead and (tokens is None or len(tokens) < lead * bs):
            return None                    # cannot re-match the lead later
        # the +1 sentinel only defeats match_prefix's len-1 cap; matching
        # compares full-block contents, never the trailing token
        probe = (tuple(int(t) for t in tokens[:lead * bs]) + (0,)
                 if lead else ())
        swap_bids = [int(a.table[slot, j]) for j in range(lead, lead + n_swap)]
        payload = None
        if n_swap:
            plan_arr = np.full(a.max_blocks_per_slot, -1, np.int32)
            plan_arr[:n_swap] = swap_bids
            payload, self.engine.cache = _call_donated(
                self._gather, self.engine.cache, jnp.asarray(plan_arr))
            # D2H, then trim the fixed-plan padding: the host keeps only
            # the n_swap real blocks (the copy detaches the view so the
            # padded gather buffer is actually freed)
            payload = jax.tree.map(
                lambda x: np.asarray(x)[:, :n_swap].copy(), payload)
        freed = a.release(slot)
        assert sorted(int(b) for b in freed) == sorted(swap_bids), \
            "swap-out must free exactly the victim's unshared blocks"
        self.engine._tables_dirty = True
        self.engine._sync_tables()
        self._streams[slot] = SwappedStream(
            slot=slot, frontier=int(frontier), n_blocks=lead + n_swap,
            shared_lead=lead, n_swap=n_swap, nbytes=nbytes, probe=probe,
            payload=payload)
        self.swap_out_bytes += nbytes
        return nbytes

    def swap_in(self, slot: int) -> tuple[int, int] | None:
        """Restore ``slot`` from the host store: re-adopt the shared
        lead from the prefix index (ref++), allocate fresh blocks for
        the host payload and scatter it back (one donated dispatch).
        Returns ``(frontier, nbytes)`` — the caller restores the cloud
        frontier and charges the H2D transfer — or None when the shared
        lead has expired from the index (the sibling died): the stream's
        host payload is dropped and it must recompute from scratch."""
        st = self._streams.pop(slot)
        a = self.engine.allocator
        if st.shared_lead:
            m = a.match_prefix(list(st.probe))
            if len(m) < st.shared_lead:
                self.expired_shares += 1
                return None
            a.adopt_prefix(slot, m[:st.shared_lead])
            self.engine._tables_dirty = True
        if st.n_swap:
            if not a.extend(slot, st.n_blocks * a.block_size):
                raise BlockPoolExhausted(
                    f"swap-in of slot {slot} needs {st.n_swap} blocks; "
                    f"pool has {a.free_blocks} free — the scheduler must "
                    f"gate swap-ins on blocks_needed()")
            new_bids = [int(a.table[slot, j])
                        for j in range(st.shared_lead, st.n_blocks)]
            W = a.max_blocks_per_slot
            plan_arr = np.full(W, -1, np.int32)
            plan_arr[:st.n_swap] = new_bids
            # re-pad the trimmed payload to the fixed (max_bps,) plan
            # (one jit specialization); pad rows route out of bounds and
            # never land
            pad = jax.tree.map(
                lambda x: jnp.asarray(np.pad(
                    x, [(0, 0), (0, W - st.n_swap)] +
                    [(0, 0)] * (x.ndim - 2))), st.payload)
            self.engine.cache = _call_donated(
                self._scatter, self.engine.cache, jnp.asarray(plan_arr),
                pad)
            self.engine._tables_dirty = True
        self.engine._sync_tables()
        self.swap_in_bytes += st.nbytes
        return st.frontier, st.nbytes

    def drop(self, slot: int) -> None:
        """Discard a swapped stream's host payload (its session ended
        without needing the cache again, or it degraded to recompute)."""
        self._streams.pop(slot, None)
