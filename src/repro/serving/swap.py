"""Host-memory KV swap tier + preemption policy layer (ROADMAP:
swap-based preemption, SLO-aware victim selection, content-addressed
host store).

Before this module the paged pool had one relief valve under pressure:
recompute-eviction — the victim's cloud frontier rewinds to zero and
its whole accepted prefix re-feeds as a from-scratch partial prefill,
burning verifier FLOPs and stalling the device pipeline the paper's
stall-free design is meant to avoid.  The swap tier adds a second
disposition: move the victim's pool blocks to a host-side block store
(one jitted read per stream — ``models/model.peek_cache_blocks`` over
every layer stack) and scatter them back into freshly allocated blocks
when pressure clears (``swap_in_blocks``).  Restored blocks are
bit-identical, so token streams are unchanged; only the modeled clock
pays the D2H+H2D round trip through ``CloudLatencyModel.host_link_gbps``.

**Content addressing** (``host_dedupe``, requires prefix sharing): host
blocks that are *registered* in the allocator's chain-hash index are
keyed by that same hash in a shared store with host-side refcounts, so
identical swapped prefixes dedupe across streams (the second victim's
chain blocks take a reference instead of a transfer) and entries whose
last referent is gone park on a host LRU instead of vanishing.  Two
extra flows ride on the store:

* **Demotion** (:meth:`demote_slot`): when a stream exits and device
  retention is off, its sole-owned registered blocks are peeked to the
  host LRU before the pool frees them — the last sharer of a recurring
  system prompt leaves its KV adoptable.
* **Adoption** (:meth:`host_match_chain` + :meth:`adopt_from_host`):
  ``alloc_prompt`` continues a new prompt's chain-hash walk beyond the
  device index into the host store and restores matching blocks by H2D
  scatter instead of re-prefill, charged as a host transfer on the
  modeled link.

Two policy decisions live here, both consumed by the scheduler:

* **Victim selection** (:func:`pick_victim`): ``youngest`` (the
  pre-swap behaviour and the identity oracle), ``most-blocks`` (free
  the most memory per eviction), ``slo-aware`` (evict the stream with
  the most remaining TTFT/deadline slack; streams without an SLO are
  preferred victims).
* **Disposition** (swap vs recompute, decided by the scheduler per
  victim): swap when the modeled round trip
  (``latency.swap_roundtrip_ms`` on the victim's measured block bytes,
  *net of host-store dedupe hits*) undercuts the modeled re-prefill
  (``latency.refeed_ms`` on its accepted frontier), or when the victim
  cannot restart at all (requests without ``seq``).

Prefix-sharing interaction: blocks mapped by a sibling (refcount > 1)
never leave the pool — the victim only *drops its reference* and
records how many leading blocks it rode on.  At swap-in those blocks
are re-adopted from the prefix index (ref++ again) when the share still
exists; if the sibling has meanwhile died and taken the blocks with it,
the swap-in degrades to recompute-eviction for that stream (the host
payload alone cannot rebuild the missing prefix KV).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.serving.trace import NULL_TRACER
from repro.serving.engine import (BlockPoolExhausted, _CHAIN_ROOT,
                                  _call_donated)

PREEMPT_POLICIES = ("youngest", "most-blocks", "slo-aware")


@dataclass(frozen=True)
class StreamSLO:
    """Per-stream latency budgets, relative to the stream's arrival on
    the shared clock.  ``ttft_ms`` bounds time to the first verified
    emission, ``deadline_ms`` time to stream completion; ``inf`` means
    unconstrained (the stream is a preferred eviction victim under the
    ``slo-aware`` policy)."""
    ttft_ms: float = float("inf")
    deadline_ms: float = float("inf")


def pick_victim(policy: str, cands: list[int], sched) -> int:
    """Choose the eviction victim among candidate slots (all hold pool
    blocks, none is the protected oldest holder).  Ties break toward
    the youngest stream, which keeps ``youngest`` the exact pre-policy
    behaviour."""
    age = sched.slot_age
    if policy == "youngest":
        return max(cands, key=lambda s: age[s])
    if policy == "most-blocks":
        a = sched.engine.allocator

        def freeable(s):
            # only sole-owned blocks actually return to the pool;
            # ref>1 shared-lead blocks merely drop a reference
            return sum(1 for j in range(int(a.n_blocks_of[s]))
                       if int(a.ref[int(a.table[s, j])]) == 1)

        return max(cands, key=lambda s: (freeable(s), age[s]))
    if policy == "slo-aware":
        now = sched.clock.now_ms
        return max(cands, key=lambda s: (sched.slot_slack_ms(s, now),
                                         age[s]))
    raise ValueError(
        f"unknown preemption policy {policy!r}; have {PREEMPT_POLICIES}")


@dataclass
class HostBlock:
    """One content-addressed host-store entry (keyed by its chain hash
    in the manager's ``_store``): the gathered single-block k/v/pos
    payload, the exact ``(prev_hash, tokens)`` identity for collision
    verification, and a host-side refcount of swapped streams that will
    restore through it.  At ref 0 the entry parks on the host LRU
    (adoptable by future sessions) until capacity evicts it."""
    payload: object                # numpy pytree, one block wide
    prev: int
    tokens: tuple
    ref: int = 0


@dataclass
class SwappedStream:
    """Host-side metadata for one swapped-out stream: the block-table
    shape it had (total blocks, how many leading ones were shared), the
    cloud frontier to restore, the per-block disposition (``chain``:
    a content-store hash, or None for a residual-payload block) and the
    anonymous residual payload."""
    slot: int
    frontier: int                  # cloud_len at swap-out
    n_blocks: int                  # blocks the slot held (incl. shared lead)
    shared_lead: int               # leading blocks left in-pool (ref dropped)
    n_swap: int                    # blocks resident on the host
    nbytes: int                    # modeled bytes moved D2H (net of dedupe)
    probe: tuple                   # tokens re-matching the shared lead
    chain: tuple = ()              # per host block: chain hash | None
    payload: object = None         # residual numpy pytree (k/v/pos per stack)

    @property
    def n_resid(self) -> int:
        """Host blocks carried privately (not content-addressed)."""
        return sum(1 for h in self.chain if h is None)


class HostSwapManager:
    """Host-side block store for swapped-out streams.

    Mechanism only: the scheduler decides *who* is evicted and *whether*
    swap beats recompute; this class executes the transfers (jitted,
    one dispatch across all layer stacks per direction, fixed
    ``(max_bps,)`` plans so jit specialization is O(1)) and keeps the
    per-stream metadata plus the shared content-addressed store.
    ``max_host_blocks`` caps total host residency (0 = unbounded) —
    ref-0 LRU entries are evicted to make room, but a victim whose
    *live* payload does not fit falls back to recompute.
    """

    def __init__(self, engine, max_host_blocks: int = 0,
                 host_dedupe: bool = True):
        self.engine = engine
        self.max_host_blocks = int(max_host_blocks)
        self.host_dedupe = bool(host_dedupe)
        self._streams: dict[int, SwappedStream] = {}   # slot -> stream, FIFO
        # content-addressed store: chain hash -> HostBlock; _lru holds
        # the ref-0 hashes in eviction order (first = oldest)
        self._store: dict[int, HostBlock] = {}
        self._lru: dict[int, None] = {}
        # peek reads without invalidating or donating — the device copy
        # stays live (retention) or is invalidated separately (release)
        self._peek = jax.jit(M.peek_cache_blocks)
        self._scatter = jax.jit(M.swap_in_blocks, donate_argnums=0)
        # tracing handle (serving/trace.py): installed by the scheduler
        # when tracing is on; NULL_TRACER keeps the guards free
        self.tracer = NULL_TRACER
        self.trace_replica = 0
        # telemetry (cumulative; pool_stats / ServerStats)
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        self.expired_shares = 0
        self.host_dedupe_hits = 0      # chain blocks shared instead of moved
        self.host_adopted_blocks = 0   # store blocks adopted at admission
        self.adopt_in_bytes = 0
        self.demoted_blocks = 0        # blocks parked at stream exit
        self._uncharged = 0            # bytes moved outside swap_out/in

    # -- introspection --------------------------------------------------
    @property
    def content_addressed(self) -> bool:
        """Whether the store keys blocks by chain hash (needs both the
        ``host_dedupe`` knob and engine-level prefix sharing — without
        registration there are no hashes to key by)."""
        return self.host_dedupe and bool(
            getattr(self.engine, "share_prefix", False))

    @property
    def swapped_blocks(self) -> int:
        """Host blocks held on behalf of live swapped streams (residual
        payloads + referenced store entries).  Ref-0 LRU entries are
        opportunistic cache, not live state — see host_lru_blocks."""
        live = sum(1 for e in self._store.values() if e.ref > 0)
        return sum(st.n_resid for st in self._streams.values()) + live

    @property
    def host_store_blocks(self) -> int:
        """All content-addressed store entries (live + LRU-parked)."""
        return len(self._store)

    @property
    def host_lru_blocks(self) -> int:
        """Store entries at ref 0 (adoptable, evictable)."""
        return len(self._lru)

    @property
    def swapped_slots(self) -> list[int]:
        """Swapped-out slots in swap-out (FIFO) order."""
        return list(self._streams)

    def holds(self, slot: int) -> bool:
        return slot in self._streams

    def blocks_needed(self, slot: int) -> int:
        """Fresh pool blocks a swap-in of ``slot`` must allocate (the
        shared lead re-adopts from the index at no block cost; device-
        tier revivals under retention can only shrink the real need)."""
        return self._streams[slot].n_swap

    def take_uncharged(self) -> int:
        """Drain host-link bytes moved outside the scheduler's explicit
        swap calls (admission adoptions, exit demotions); the scheduler
        charges them to the modeled clock."""
        n, self._uncharged = self._uncharged, 0
        return n

    def _host_total(self) -> int:
        return (sum(st.n_resid for st in self._streams.values())
                + len(self._store))

    def _store_match(self, h: int, prev: int, blk: tuple) -> bool:
        e = self._store.get(h)
        return e is not None and (e.prev, e.tokens) == (prev, blk)

    def _store_take(self, h: int, prev: int, blk: tuple) -> bool:
        """Dedupe hit: an identical block is already host-resident —
        take a reference instead of a transfer."""
        if not self._store_match(h, prev, blk):
            return False
        e = self._store[h]
        e.ref += 1
        self._lru.pop(h, None)
        self.host_dedupe_hits += 1
        return True

    def _touch_lru(self, h: int) -> None:
        """Refresh a ref-0 entry to MRU position (a hit is evidence of
        reuse)."""
        if h in self._lru:
            del self._lru[h]
            self._lru[h] = None

    def _release_chain(self, st: SwappedStream) -> None:
        """Drop a stream's references on its content-store entries."""
        for h in st.chain:
            if h is None:
                continue
            e = self._store.get(h)
            if e is None:
                continue
            e.ref = max(0, e.ref - 1)
            if e.ref == 0:
                self._lru[h] = None

    def _enforce_host_cap(self, keep=()) -> None:
        """Evict ref-0 LRU entries (oldest first) until total host
        residency fits ``max_host_blocks``."""
        if not self.max_host_blocks:
            return
        keep = set(keep)
        for h in list(self._lru):
            if self._host_total() <= self.max_host_blocks:
                break
            if h in keep:
                continue
            del self._lru[h]
            self._store.pop(h, None)

    def _split(self, bids: list[int]) -> list:
        """Per-block disposition for a victim's host-bound blocks:
        ``(h, prev, tokens, bid)`` for registered, realized blocks
        (content-addressed) or None (anonymous residual — unregistered
        decode/tail blocks, or everything when dedupe is off)."""
        if not self.content_addressed:
            return [None] * len(bids)
        a = self.engine.allocator
        out = []
        for b in bids:
            info = a.chain_of(b)
            if info is not None and b not in a._fill:
                out.append((info[0], info[1], info[2], b))
            else:
                out.append(None)
        return out

    def plan(self, slot: int) -> tuple[int, int, int] | None:
        """Whether ``slot`` can swap out, and at what cost: returns
        ``(shared_lead, n_swap, nbytes)`` or None when swap is not
        possible — no blocks, already swapped, an interior (non-leading)
        shared block (only leading prompt blocks can re-adopt), or the
        victim's live payload cannot fit the host cap even after LRU
        eviction.  ``nbytes`` is net of content-store dedupe hits, so
        the scheduler's swap-vs-recompute disposition sees the real
        (cheaper) transfer."""
        a = self.engine.allocator
        n = int(a.n_blocks_of[slot])
        if n == 0 or slot in self._streams:
            return None
        bids = [int(a.table[slot, j]) for j in range(n)]
        shared = [j for j, b in enumerate(bids) if int(a.ref[b]) > 1]
        if shared != list(range(len(shared))):
            return None
        n_swap = n - len(shared)
        entries = self._split(bids[len(shared):])
        hits = {e[0] for e in entries
                if e is not None and self._store_match(e[0], e[1], e[2])}
        n_new = n_swap - len(hits)
        if self.max_host_blocks:
            evictable = sum(1 for h in self._lru if h not in hits)
            if self._host_total() - evictable + n_new > self.max_host_blocks:
                return None
        return len(shared), n_swap, n_new * self.engine.block_bytes()

    # -- transfers ------------------------------------------------------
    def swap_out(self, slot: int, tokens, frontier: int) -> int | None:
        """Evict ``slot`` to the host store: peek its unshared blocks
        (k/v/pos across every layer stack), file registered ones in the
        content-addressed store (dedupe hits take a reference instead of
        a transfer), keep the rest as the stream's residual payload,
        drop its reference on shared-lead blocks, and return all its
        pool blocks to the allocator (truly freed ones are invalidated;
        under retention, registered blocks park on the cached-free LRU
        instead).  ``tokens`` must cover the shared lead (the stream's
        prompt) so the lead can be re-matched at swap-in.  Returns the
        modeled bytes moved, or None when the swap is not possible (the
        caller falls back to recompute-eviction)."""
        p = self.plan(slot)
        if p is None:
            return None
        lead, n_swap, nbytes = p
        a = self.engine.allocator
        bs = a.block_size
        if lead and (tokens is None or len(tokens) < lead * bs):
            return None                    # cannot re-match the lead later
        # the +1 sentinel only defeats match_prefix's len-1 cap; matching
        # compares full-block contents, never the trailing token
        probe = (tuple(int(t) for t in tokens[:lead * bs]) + (0,)
                 if lead else ())
        bids = [int(a.table[slot, j]) for j in range(lead, lead + n_swap)]
        entries = self._split(bids)
        chain: list = []
        new_entries: list = []
        for e, b in zip(entries, bids):
            if e is None:
                chain.append(None)
                continue
            h, prev, blk, _b = e
            chain.append(h)
            if not self._store_take(h, prev, blk):
                new_entries.append(e)
        resid_bids = [b for e, b in zip(entries, bids) if e is None]
        move_bids = [e[3] for e in new_entries] + resid_bids
        payload = None
        if move_bids:
            plan_arr = np.full(a.max_blocks_per_slot, -1, np.int32)
            plan_arr[:len(move_bids)] = move_bids
            peeked = self._peek(self.engine.cache, jnp.asarray(plan_arr))
            # D2H, then trim the fixed-plan padding: the host keeps only
            # the real blocks (the copy detaches the view so the padded
            # gather buffer is actually freed)
            peeked = jax.tree.map(
                lambda x: np.asarray(x)[:, :len(move_bids)].copy(), peeked)
            for i, (h, prev, blk, _b) in enumerate(new_entries):
                one = jax.tree.map(lambda x: x[:, i:i + 1].copy(), peeked)
                self._store[h] = HostBlock(payload=one, prev=prev,
                                           tokens=blk, ref=1)
            if resid_bids:
                k0 = len(new_entries)
                payload = jax.tree.map(lambda x: x[:, k0:].copy(), peeked)
        freed = a.release(slot)
        self.engine._invalidate_blocks(int(b) for b in freed)
        self.engine._tables_dirty = True
        self.engine._sync_tables()
        self._streams[slot] = SwappedStream(
            slot=slot, frontier=int(frontier), n_blocks=lead + n_swap,
            shared_lead=lead, n_swap=n_swap, nbytes=nbytes, probe=probe,
            chain=tuple(chain), payload=payload)
        self._enforce_host_cap(keep=[h for h in chain if h is not None])
        self.swap_out_bytes += nbytes
        return nbytes

    def swap_in(self, slot: int) -> tuple[int, int] | None:
        """Restore ``slot`` from the host store: re-adopt the shared
        lead from the prefix index (ref++), then rebuild the remaining
        blocks in position order — under device retention a chain block
        still registered in the pool is *revived* in place (no
        transfer); everything else scatters from the content store /
        residual payload into freshly allocated blocks (one donated
        dispatch).  Restored chain blocks re-register, so the share
        survives the round trip.  Returns ``(frontier, nbytes_moved)``
        — the caller restores the cloud frontier and charges the actual
        H2D bytes — or None when the shared lead has expired from the
        index (the sibling died): the stream's host references are
        dropped and it must recompute from scratch."""
        st = self._streams.pop(slot)
        a = self.engine.allocator
        if st.shared_lead:
            m = a.match_prefix(list(st.probe))
            if len(m) < st.shared_lead:
                self.expired_shares += 1
                self._release_chain(st)
                return None
            a.adopt_prefix(slot, m[:st.shared_lead])
            self.engine._tables_dirty = True
        scatter_bids: list[int] = []
        parts: list = []
        ri = 0
        for h in st.chain:
            if h is not None:
                e = self._store[h]
                bid = a._index.get(h) if a.retain_prefix else None
                if (bid is not None and bid not in a._fill
                        and a._contents.get(bid) == (e.prev, e.tokens)):
                    # device tier still holds this block (cached-free or
                    # live under a sibling): revive instead of scatter
                    a.map_block(slot, bid)
                    self.engine._tables_dirty = True
                    part = None
                else:
                    part = e.payload
                e.ref = max(0, e.ref - 1)
                if e.ref == 0:
                    self._lru[h] = None
            else:
                part = jax.tree.map(lambda x: x[:, ri:ri + 1], st.payload)
                ri += 1
            if part is not None:
                b = a.append_fresh(slot)
                if b is None:
                    raise BlockPoolExhausted(
                        f"swap-in of slot {slot} needs a fresh block; "
                        f"pool is dry — the scheduler must gate swap-ins "
                        f"on blocks_needed()")
                if h is not None:
                    e = self._store[h]
                    a.register_block(b, h, e.prev, e.tokens)
                scatter_bids.append(b)
                parts.append(part)
        moved = 0
        if scatter_bids:
            self.engine._flush_reclaims()
            W = a.max_blocks_per_slot
            plan_arr = np.full(W, -1, np.int32)
            plan_arr[:len(scatter_bids)] = scatter_bids
            merged = parts[0] if len(parts) == 1 else jax.tree.map(
                lambda *xs: np.concatenate(xs, axis=1), *parts)
            # re-pad the trimmed payload to the fixed (max_bps,) plan
            # (one jit specialization); pad rows route out of bounds and
            # never land
            pad = jax.tree.map(
                lambda x: jnp.asarray(np.pad(
                    x, [(0, 0), (0, W - len(scatter_bids))] +
                    [(0, 0)] * (x.ndim - 2))), merged)
            self.engine.cache = _call_donated(
                self._scatter, self.engine.cache, jnp.asarray(plan_arr),
                pad)
            self.engine._tables_dirty = True
            moved = len(scatter_bids) * self.engine.block_bytes()
        self.engine._sync_tables()
        self.swap_in_bytes += moved
        return st.frontier, moved

    def drop(self, slot: int) -> None:
        """Discard a swapped stream's host state (its session ended
        without needing the cache again, or it degraded to recompute):
        the residual payload dies with the stream; content-store
        references are dropped (ref-0 entries stay adoptable on the
        host LRU until capacity evicts them)."""
        st = self._streams.pop(slot, None)
        if st is not None:
            self._release_chain(st)

    # -- content-addressed admission/exit flows -------------------------
    def host_match_chain(self, tokens, start_j: int) -> list[tuple]:
        """Continue a prompt's chain-hash walk beyond the device match
        (``start_j`` full blocks already adopted) against the content-
        addressed host store.  Returns ``[(hash, entry), ...]`` in chain
        order, stopping at the first miss; the same ``len(tokens) - 1``
        cap as ``match_prefix`` applies (a fully cached prompt still
        feeds its last token)."""
        if not self.content_addressed:
            return []
        a = self.engine.allocator
        if len(tokens) > a.s_max:
            return []
        bs = a.block_size
        n_full = min((len(tokens) - 1) // bs, a.max_blocks_per_slot)
        out = []
        h = _CHAIN_ROOT
        for j in range(n_full):
            blk = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            prev, h = h, hash((h, blk))
            if j < start_j:
                continue
            e = self._store.get(h)
            if e is None or (e.prev, e.tokens) != (prev, blk):
                break
            out.append((h, e))
        return out

    def adopt_from_host(self, slot: int, start_j: int,
                        entries: list[tuple]) -> int:
        """H2D-adopt host-store chain blocks into ``slot``'s freshly
        allocated blocks ``[start_j, start_j + len(entries))``: scatter
        the stored k/v/pos in one dispatch and register the blocks
        *realized* (their content is already on device, so a later
        divergent write must fork/unregister, never skip).  The engine's
        ``alloc_prompt`` has already allocated the destinations and
        flushed reclaims.  Returns bytes moved (also accumulated for
        ``take_uncharged``)."""
        a = self.engine.allocator
        bids = [int(a.table[slot, start_j + i]) for i in range(len(entries))]
        W = a.max_blocks_per_slot
        plan_arr = np.full(W, -1, np.int32)
        plan_arr[:len(bids)] = bids
        parts = [e.payload for _h, e in entries]
        merged = parts[0] if len(parts) == 1 else jax.tree.map(
            lambda *xs: np.concatenate(xs, axis=1), *parts)
        pad = jax.tree.map(
            lambda x: jnp.asarray(np.pad(
                x, [(0, 0), (0, W - len(bids))] +
                [(0, 0)] * (x.ndim - 2))), merged)
        self.engine.cache = _call_donated(
            self._scatter, self.engine.cache, jnp.asarray(plan_arr), pad)
        self.engine._tables_dirty = True
        for (h, e), b in zip(entries, bids):
            a.register_block(b, h, e.prev, e.tokens)
            self._touch_lru(h)
        moved = len(entries) * self.engine.block_bytes()
        self.host_adopted_blocks += len(entries)
        self.adopt_in_bytes += moved
        self._uncharged += moved
        if self.tracer.enabled:
            self.tracer.instant("host_adopt", replica=self.trace_replica,
                                slot=slot, n=len(entries))
        return moved

    def demote_slot(self, slot: int) -> int:
        """Content-addressed demotion at stream exit: peek the slot's
        sole-owned (ref == 1), registered, realized blocks that the host
        store does not already hold and park them on the host LRU —
        the last live sharer of a recurring prefix leaves its KV
        adoptable by future sessions even though the device pool frees
        the blocks.  Called by ``engine.reset_slot`` *before* the
        allocator release (the pool content must still be readable).
        Returns bytes moved (accumulated for ``take_uncharged``)."""
        if not self.content_addressed or slot in self._streams:
            return 0
        a = self.engine.allocator
        cand = []
        for j in range(int(a.n_blocks_of[slot])):
            b = int(a.table[slot, j])
            if b < 0 or int(a.ref[b]) != 1 or b in a._fill:
                continue
            info = a.chain_of(b)
            if info is None:
                continue
            h, prev, blk = info
            if self._store_match(h, prev, blk):
                self._touch_lru(h)
                continue
            cand.append((h, prev, blk, b))
        if self.max_host_blocks:
            # demotion never displaces live payload: cap the candidates
            # to what fits after evicting stale ref-0 LRU entries
            room = (self.max_host_blocks - self._host_total()
                    + len(self._lru))
            cand = cand[:max(0, room)]
        if not cand:
            return 0
        W = a.max_blocks_per_slot
        for off in range(0, len(cand), W):
            grp = cand[off:off + W]
            plan_arr = np.full(W, -1, np.int32)
            plan_arr[:len(grp)] = [c[3] for c in grp]
            peeked = self._peek(self.engine.cache, jnp.asarray(plan_arr))
            peeked = jax.tree.map(
                lambda x: np.asarray(x)[:, :len(grp)].copy(), peeked)
            for i, (h, prev, blk, _b) in enumerate(grp):
                one = jax.tree.map(lambda x: x[:, i:i + 1].copy(), peeked)
                self._store[h] = HostBlock(payload=one, prev=prev,
                                           tokens=blk)
                self._lru[h] = None
        self.demoted_blocks += len(cand)
        moved = len(cand) * self.engine.block_bytes()
        self._uncharged += moved
        if self.tracer.enabled:
            self.tracer.instant("host_demote", replica=self.trace_replica,
                                slot=slot, n=len(cand))
        self._enforce_host_cap()
        return moved
