"""Unified tracing layer: lifecycle spans, stall attribution, Perfetto.

Every serving component (device coroutine, scheduler, swap tier, block
allocator, router, gateway) stamps structured events through one shared
:class:`Tracer` bound to the run's clock — ``SimClock`` and
``RealClock`` expose the same ``now_ms`` axis (serving/link.py), so the
recording code is identical under discrete-event simulation and
wall-clock serving.  Three artifacts come out of the same event stream:

* **Engine-side spans** — every modeled cost the scheduler charges to
  the shared clock (prefill / verify / decode iterations, swap D2H/H2D
  transfers, exit-time demotions, idle fast-forwards) becomes a typed
  span tagged with the replica, the request ids it served, the slot it
  touched, and the token/byte volume.  These replace the vestigial
  ``Timeline.events`` ``(kind, dt)`` tuples that used to pile up per
  stream: the charge stream now lives once, globally, typed.

* **Per-stream async spans** — each stream is an async track (queued →
  slot assignment → device draft / PI overlap / stall windows → each
  verify round trip → emits → done), anchored at ``session.start_ms``
  on the shared clock.

* **Stall attribution** — every stream's end-to-end time decomposes
  into *exclusive* buckets that sum to its wall time:

  ===========  ======================================================
  device       on-device SLM compute (draft, prefill, PI overlap)
  cloud        verify/prefill iterations that actually fed this stream
  link         WAN uplink/downlink transfer (unmasked portion)
  queue        admission queueing before the stream's prompt prefill
               executed (no slot / no blocks)
  batch_wait   shared-clock time spent behind *other* streams' work
               while this stream's request was in flight
  swap         host-swap D2H/H2D transfers charged to this stream's
               slot
  preempted    serving work that was later thrown away by a
               recompute-eviction rewind of this stream's request
  other        unattributed residue: stalls recorded while tracing is
               off, plus (under ``RealClock`` without pacing) host
               compute the latency model does not cover
  ===========  ======================================================

  The decomposition walks the round trip in time order — uplink, then
  the scheduler's charge spans inside the request's in-flight window
  ``[arrival, completion]``, then downlink — and drops the leading
  ``overlap_ms`` hidden by stall-free parallel inference (the PI
  overlap masks the *front* of the round trip; the stall is its tail).
  ``StreamTimeline.bucket_sum == t_ms`` holds exactly by construction.

Tracing must never change behavior: recording is strictly passive (no
clock advances, no RNG draws), so token streams are byte-identical with
tracing on or off.  When disabled, the module-level :data:`NULL_TRACER`
is installed everywhere and every hot-path call site guards on
``tracer.enabled`` — the disabled path allocates nothing.

Export is Chrome trace-event JSON (``{"traceEvents": [...]}``) loadable
in ``ui.perfetto.dev``: one process per replica with an engine track
plus one track per touched slot, and a ``streams`` process carrying the
per-stream async spans.  See docs/observability.md.
"""
from __future__ import annotations

import json
from bisect import bisect_left
from dataclasses import dataclass

# engine-side span kinds whose cost is a host-swap transfer for a slot
_SWAP_KINDS = ("swap_out", "swap_in", "swap_demote")

# fixed Prometheus histogram ladder for TTFT/TPOT/E2E (milliseconds)
HIST_BUCKETS_MS = (5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                   1000.0, 2500.0, 5000.0, 10000.0, 30000.0)


# ---------------------------------------------------------------------------
# Prometheus histogram helpers (gateway /metrics; serving/gateway/protocol)
# ---------------------------------------------------------------------------

def hist_new() -> dict:
    """Empty cumulative histogram over :data:`HIST_BUCKETS_MS`.

    ``buckets[i]`` counts samples ``<= le[i]`` (Prometheus cumulative
    semantics); the trailing entry is the ``+Inf`` bucket (== count)."""
    return {"le": list(HIST_BUCKETS_MS),
            "buckets": [0] * (len(HIST_BUCKETS_MS) + 1),
            "sum": 0.0, "count": 0}


def hist_add(h: dict, v: float) -> None:
    for i, le in enumerate(h["le"]):
        if v <= le:
            h["buckets"][i] += 1
    h["buckets"][-1] += 1
    h["sum"] += float(v)
    h["count"] += 1


def hist_from(samples) -> dict:
    h = hist_new()
    for v in samples:
        hist_add(h, float(v))
    return h


def hist_merge(hists) -> dict:
    """Fold cumulative histograms (identical ladders) into one."""
    out = hist_new()
    for h in hists:
        for i, c in enumerate(h["buckets"]):
            out["buckets"][i] += c
        out["sum"] += h["sum"]
        out["count"] += h["count"]
    return out


# ---------------------------------------------------------------------------
# Per-stream timeline (absorbs the old serving/link.py Timeline)
# ---------------------------------------------------------------------------

@dataclass
class StreamTimeline:
    """Simulated wall-clock of one request stream, decomposed into the
    exclusive stall buckets above.  Every path that advances ``t_ms``
    credits exactly one bucket, so ``bucket_sum == t_ms`` always holds
    — with tracing off the stall portion simply lands in ``other_ms``.

    ``comm_ms`` keeps its legacy meaning: communication time, including
    round-trip time *masked* by PI overlap (which does not advance
    ``t_ms`` and therefore is not a bucket)."""
    t_ms: float = 0.0
    stall_ms: float = 0.0
    compute_ms: float = 0.0        # == the "device" bucket
    comm_ms: float = 0.0
    energy_j: float = 0.0
    # -- exclusive stall buckets (device bucket is compute_ms) --
    cloud_ms: float = 0.0
    link_ms: float = 0.0
    queue_ms: float = 0.0
    batch_wait_ms: float = 0.0
    swap_ms: float = 0.0
    preempted_ms: float = 0.0
    other_ms: float = 0.0

    _CAT = {"cloud": "cloud_ms", "link": "link_ms", "queue": "queue_ms",
            "wait": "batch_wait_ms", "swap": "swap_ms",
            "preempted": "preempted_ms", "other": "other_ms"}

    def advance(self, dt: float, kind: str):
        self.t_ms += dt
        if kind == "stall":
            self.stall_ms += dt
            self.other_ms += dt    # unattributed (blocking path / no trace)
        elif kind == "compute":
            self.compute_ms += dt
        elif kind == "comm":
            self.comm_ms += dt
            self.link_ms += dt

    def advance_stall(self, stall_ms: float, up_ms: float, cloud_parts,
                      down_ms: float, overlap_ms: float) -> None:
        """Advance by one verify round trip's pipeline stall and
        attribute it.  ``cloud_parts`` is ``Tracer.window_parts`` for
        the request's in-flight window (``None`` when tracing is off:
        the whole stall lands in ``other``).  The round trip in time
        order is uplink → cloud window → downlink; the leading
        ``overlap_ms`` was masked by PI compute (already counted as
        device time), so it is dropped from the front and only the tail
        is attributed.  Buckets gain exactly ``stall_ms`` total."""
        self.t_ms += stall_ms
        self.stall_ms += stall_ms
        if stall_ms <= 0.0:
            return
        if cloud_parts is None:
            self.other_ms += stall_ms
            return
        rem = overlap_ms
        categorized = 0.0
        for cat, dur in ([("link", up_ms)] + list(cloud_parts)
                         + [("link", down_ms)]):
            if dur <= 0.0:
                continue
            hide = min(rem, dur)
            rem -= hide
            keep = min(dur - hide, stall_ms - categorized)
            if keep > 0.0:
                f = self._CAT[cat]
                setattr(self, f, getattr(self, f) + keep)
                categorized += keep
        # float residue (and any uncovered window time) stays exclusive
        self.other_ms += stall_ms - categorized

    def buckets(self) -> dict:
        return {"device": self.compute_ms, "cloud": self.cloud_ms,
                "link": self.link_ms, "queue": self.queue_ms,
                "batch_wait": self.batch_wait_ms, "swap": self.swap_ms,
                "preempted": self.preempted_ms, "other": self.other_ms}

    @property
    def bucket_sum(self) -> float:
        return (self.compute_ms + self.cloud_ms + self.link_ms
                + self.queue_ms + self.batch_wait_ms + self.swap_ms
                + self.preempted_ms + self.other_ms)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class _NullTracer:
    """Disabled tracer: every method is a no-op and ``enabled`` is
    False, so hot paths guard with one attribute read and never build
    event payloads — zero allocation on the disabled path."""
    enabled = False
    clock = None

    def __bool__(self):
        return False

    def span(self, *a, **k):
        pass

    def instant(self, *a, **k):
        pass

    def stream_begin(self, *a, **k):
        return -1

    def stream_child(self, *a, **k):
        pass

    def stream_instant(self, *a, **k):
        pass

    def stream_end(self, *a, **k):
        pass

    def window_parts(self, *a, **k):
        return None

    def to_events(self):
        return []

    def export(self, path):
        raise RuntimeError("tracing is disabled (NULL_TRACER)")


NULL_TRACER = _NullTracer()


class _StreamRec:
    __slots__ = ("uid", "name", "t0", "t1", "replica", "meta",
                 "children", "instants", "end_meta")

    def __init__(self, uid, name, t0, replica, meta):
        self.uid = uid
        self.name = name
        self.t0 = t0
        self.t1 = None
        self.replica = replica
        self.meta = meta or {}
        self.children = []        # (name, t0, t1)
        self.instants = []        # (name, t, n)
        self.end_meta = None


class Tracer:
    """Records spans/instants stamped on the shared clock.

    One tracer serves a whole fleet: replicas tag their events with
    their index, and with one shared clock (and one engine thread in
    the gateway) the charge stream is globally chronological — which is
    what lets :meth:`window_parts` decompose any request's in-flight
    window by bisection.  ``max_records`` bounds memory on long
    gateway runs: past the cap new engine spans/instants are counted
    but dropped (attribution then falls back to the ``other`` bucket
    for windows it can no longer cover)."""

    def __init__(self, clock, *, max_records: int = 1 << 20):
        self.clock = clock
        self.enabled = True
        self.max_records = max_records
        self.dropped = 0
        self._spans = []          # (t0,t1,kind,replica,rids,slot,tokens,nbytes)
        self._span_t0s = []       # parallel array for bisect
        self._instants = []       # (t, kind, replica, slot, rids, n)
        self._rewinds = []        # (t, replica, rids) — preemption rewinds
        self._streams: dict[int, _StreamRec] = {}
        self._uid = 0

    # -- engine-side recording -----------------------------------------
    def span(self, t0: float, t1: float, kind: str, replica: int = 0,
             rids=(), slot: int = -1, tokens: int = 0,
             nbytes: int = 0) -> None:
        if len(self._spans) >= self.max_records:
            self.dropped += 1
            return
        self._spans.append((t0, t1, kind, replica, rids, slot, tokens,
                            nbytes))
        self._span_t0s.append(t0)

    def instant(self, kind: str, t: float | None = None, replica: int = 0,
                slot: int = -1, rids=(), n: int = 0) -> None:
        if t is None:
            t = self.clock.now_ms
        if kind == "rewind":
            self._rewinds.append((t, replica, rids))
        if len(self._instants) >= self.max_records:
            self.dropped += 1
            return
        self._instants.append((t, kind, replica, slot, rids, n))

    # -- per-stream lifecycle ------------------------------------------
    def stream_begin(self, name: str, t: float, *, replica: int = 0,
                     meta: dict | None = None) -> int:
        self._uid += 1
        self._streams[self._uid] = _StreamRec(self._uid, name, t, replica,
                                              meta)
        return self._uid

    def stream_child(self, uid: int, name: str, t0: float,
                     t1: float) -> None:
        rec = self._streams.get(uid)
        if rec is not None:
            rec.children.append((name, t0, t1))

    def stream_instant(self, uid: int, name: str, t: float,
                       n: int = 0) -> None:
        rec = self._streams.get(uid)
        if rec is not None:
            rec.instants.append((name, t, n))

    def stream_end(self, uid: int, t: float, *,
                   meta: dict | None = None) -> None:
        rec = self._streams.get(uid)
        if rec is not None:
            rec.t1 = t
            rec.end_meta = meta or {}

    # -- stall attribution ---------------------------------------------
    def window_parts(self, a: float, c: float, *, replica: int = 0,
                     slot: int = -1, vrid: int = -1,
                     prefill_rid: int | None = None) -> list:
        """Decompose the in-flight window ``[a, c]`` of one verify
        request into chronological ``(category, ms)`` parts.

        Charge spans inside the window classify as:

        * ``cloud`` — iterations that fed this request (``vrid``) or
          executed this stream's prompt prefill (``prefill_rid``);
        * ``preempted`` — such serving spans that a later
          recompute-eviction rewind of this request threw away;
        * ``swap`` — host-swap transfers charged to this stream's slot;
        * ``queue`` — non-serving time before the stream's prompt
          prefill executed (admission queueing: no slot / no blocks);
        * ``wait`` — every other charge in the window (other streams'
          iterations, scheduler overhead, idle fast-forwards);
        * ``other`` — window time no recorded span covers (zero under
          ``SimClock``; real host compute under ``RealClock``).

        The parts sum exactly to ``c - a``.  Purely read-only."""
        if c <= a:
            return []
        spans = self._spans
        lo = bisect_left(self._span_t0s, a)
        if lo > 0:
            lo -= 1                # the span straddling ``a``
        rw = 0.0                   # latest rewind of vrid inside the window
        for t, rep, rids in self._rewinds:
            if a <= t <= c and rep == replica and vrid in rids:
                rw = max(rw, t)
        boundary = None            # start of this stream's prompt prefill
        raw = []                   # [cat, clipped_dur, span_t1]
        covered = 0.0
        for i in range(lo, len(spans)):
            t0, t1, kind, rep, rids, sslot, _, _ = spans[i]
            if t0 >= c:
                break
            d = min(t1, c) - max(t0, a)
            if d <= 0.0:
                continue
            serving = (rep == replica
                       and (vrid in rids
                            or (prefill_rid is not None
                                and prefill_rid in rids)))
            if serving:
                if (boundary is None and kind == "prefill"
                        and prefill_rid is not None
                        and prefill_rid in rids):
                    boundary = t0
                cat = "preempted" if t1 <= rw else "cloud"
            elif (kind in _SWAP_KINDS and rep == replica
                  and sslot == slot):
                cat = "swap"
            else:
                cat = "wait"
            raw.append([cat, d, t1])
            covered += d
        if boundary is not None:
            # charges that finished before our prompt prefill began are
            # admission queueing, not batch wait: the stream had no slot
            for p in raw:
                if p[0] == "wait" and p[2] <= boundary:
                    p[0] = "queue"
        out = []
        for cat, d, _ in raw:
            if out and out[-1][0] == cat:
                out[-1] = (cat, out[-1][1] + d)
            else:
                out.append((cat, d))
        resid = (c - a) - covered
        if resid > 1e-9:
            out.append(("other", resid))
        return out

    # -- export ---------------------------------------------------------
    @staticmethod
    def _us(t_ms: float) -> float:
        return t_ms * 1000.0

    def to_events(self) -> list[dict]:
        """Chrome trace-event list: pid 0 carries the per-stream async
        spans; pid ``1 + replica`` carries that replica's engine track
        (tid 0) and one track per touched slot (tid ``1 + slot``)."""
        ev = [{"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
               "args": {"name": "streams"}}]
        replicas, slots = set(), set()
        for t0, t1, kind, rep, rids, slot, tokens, nbytes in self._spans:
            replicas.add(rep)
            tid = 1 + slot if (slot >= 0 and kind in _SWAP_KINDS) else 0
            if tid > 0:
                slots.add((rep, slot))
            args = {}
            if rids:
                args["rids"] = [int(r) for r in rids]
            if slot >= 0:
                args["slot"] = slot
            if tokens:
                args["tokens"] = int(tokens)
            if nbytes:
                args["nbytes"] = int(nbytes)
            ev.append({"ph": "X", "name": kind, "cat": "engine",
                       "ts": self._us(t0),
                       "dur": max(self._us(t1 - t0), 0.0),
                       "pid": 1 + rep, "tid": tid, "args": args})
        for t, kind, rep, slot, rids, n in self._instants:
            replicas.add(rep)
            tid = 1 + slot if slot >= 0 else 0
            if tid > 0:
                slots.add((rep, slot))
            args = {}
            if rids:
                args["rids"] = [int(r) for r in rids]
            if n:
                args["n"] = int(n)
            ev.append({"ph": "i", "s": "t", "name": kind, "cat": "engine",
                       "ts": self._us(t), "pid": 1 + rep, "tid": tid,
                       "args": args})
        for rec in self._streams.values():
            sid = str(rec.uid)
            name = f"{rec.name}-{rec.uid}"
            ev.append({"ph": "b", "name": name, "cat": "stream", "id": sid,
                       "ts": self._us(rec.t0), "pid": 0, "tid": 0,
                       "args": dict(rec.meta)})
            for cname, t0, t1 in rec.children:
                ev.append({"ph": "b", "name": cname, "cat": "stream",
                           "id": sid, "ts": self._us(t0), "pid": 0,
                           "tid": 0, "args": {}})
                ev.append({"ph": "e", "name": cname, "cat": "stream",
                           "id": sid, "ts": self._us(max(t1, t0)),
                           "pid": 0, "tid": 0, "args": {}})
            for iname, t, n in rec.instants:
                ev.append({"ph": "n", "name": iname, "cat": "stream",
                           "id": sid, "ts": self._us(t), "pid": 0,
                           "tid": 0, "args": ({"n": int(n)} if n else {})})
            t_end = rec.t1 if rec.t1 is not None else rec.t0
            ev.append({"ph": "e", "name": name, "cat": "stream", "id": sid,
                       "ts": self._us(max(t_end, rec.t0)), "pid": 0,
                       "tid": 0, "args": dict(rec.end_meta or {})})
        for rep in sorted(replicas):
            ev.append({"ph": "M", "name": "process_name", "pid": 1 + rep,
                       "tid": 0, "args": {"name": f"replica-{rep}"}})
            ev.append({"ph": "M", "name": "thread_name", "pid": 1 + rep,
                       "tid": 0, "args": {"name": "engine"}})
        for rep, slot in sorted(slots):
            ev.append({"ph": "M", "name": "thread_name", "pid": 1 + rep,
                       "tid": 1 + slot, "args": {"name": f"slot-{slot}"}})
        return ev

    def to_dict(self) -> dict:
        return {"traceEvents": self.to_events(), "displayTimeUnit": "ms",
                "synera": {"spans": len(self._spans),
                           "instants": len(self._instants),
                           "streams": len(self._streams),
                           "dropped": self.dropped}}

    def export(self, path: str) -> str:
        """Write the Perfetto/Chrome trace-event JSON file."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path
