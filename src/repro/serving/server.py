"""Multi-tenant serving front-end: ``SyneraServer`` + ``DeviceSession``.

This is the non-blocking redesign of the serving layer (ROADMAP: scale
items).  The server owns the shared cloud side — ``CloudEngine``,
``VerificationAwareScheduler`` and one global discrete-event
``SimClock`` — while each ``DeviceSession`` wraps one device stream's
generation coroutine (``DeviceRuntime.generate_steps``) together with
its ``CloudClient`` handle.

Event-loop semantics
--------------------

Each session is a state machine::

    running --(yields verify, no slot yet)--> wait_slot
    running --(yields verify, has slot)-----> wait_cloud
    wait_slot --(prefill_done)--------------> wait_cloud
    wait_cloud --(verify_done)--------------> running
    running --(StopIteration)---------------> done

One ``step()`` of the loop first advances every *running* session until
it either finishes or parks on a cloud round trip — device draft
compute advances only that stream's private timeline — then executes
one scheduler iteration on the shared clock.  Because all runnable
streams are drained before the cloud runs, verification requests from
many sessions coexist in the scheduler's queues and one verify
iteration genuinely packs chunks from multiple slots (Algorithm 1 at
scale, §4.5).

Clocks: a session's device timeline is stream-relative; ``start_ms``
anchors it on the shared absolute clock.  A ``CloudCall`` sent at
device time ``t`` arrives at the cloud at ``start_ms + t + uplink``;
the scheduler fast-forwards to arrivals when idle and advances by
iteration cost when busy, so the reply's ``cloud_ms`` (completion -
arrival) includes genuine cross-stream queueing.  The stall the device
experiences is ``max(uplink + cloud_ms + downlink - overlap, 0)``,
exactly as in the blocking path — which is the ``concurrency=1``
special case and reproduces it metric-for-metric.
"""
from __future__ import annotations

from collections import deque
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.serving.device import CloudCall, CloudReply, DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.link import CloudLatencyModel, SimClock
from repro.serving.scheduler import VerificationAwareScheduler
from repro.serving.synergy import CloudClient
from repro.serving.trace import NULL_TRACER, hist_from, hist_merge, hist_new

RUNNING = "running"
WAIT_SLOT = "wait_slot"    # verify ready but prompt prefill not yet done
WAIT_CLOUD = "wait_cloud"  # verify in flight
DONE = "done"


@dataclass
class _SparseDist:
    """Compressed-dist shape ``CloudClient.verify_async`` consumes
    (``d.idx`` / ``d.val``) — used to rebuild a parked verify's dists
    from a scheduler ``VerifyRequest.q_sparse`` on session export."""
    idx: object
    val: object


@dataclass
class ServerStats:
    """Batching + memory telemetry for one serving run.

    The scheduler counters describe Algorithm-1 packing efficiency; the
    block-pool fields (meaningful when the engine runs
    ``cache_impl="paged"``) describe the memory-bound admission state —
    free/used/peak blocks, bytes actually backing live KV versus the
    dense reservation, and how many preemptions the pool forced.
    """
    iterations: int = 0
    prefill_iterations: int = 0
    verify_iterations: int = 0
    mean_verify_occupancy: float = 0.0
    max_verify_occupancy: int = 0
    mean_packed_tokens: float = 0.0
    sim_ms: float = 0.0
    waiting_sessions: int = 0          # admitted but not yet holding a slot
    # -- block pool (paged cache) --
    cache_impl: str = "dense"
    block_size: int = 0
    n_blocks: int = 0
    free_blocks: int = 0
    cached_free_blocks: int = 0        # ref-0 retained prefix blocks (LRU)
    used_blocks: int = 0
    peak_used_blocks: int = 0
    kv_cache_bytes: int = 0
    kv_bytes_in_use: int = 0
    kv_bytes_peak: int = 0
    preemptions: int = 0
    preempted_refed_tokens: int = 0
    # -- eviction disposition (host swap tier, serving/swap.py) --
    preempt_policy: str = "youngest"
    swap: bool = False                 # host swap tier enabled
    recompute_evictions: int = 0       # evictions that refeed from scratch
    swap_evictions: int = 0            # evictions parked in host memory
    swap_expirations: int = 0          # swap-ins degraded: shared lead died
    swapped_blocks: int = 0            # blocks currently in the host store
    swap_out_bytes: int = 0            # cumulative D2H payload bytes
    swap_in_bytes: int = 0             # cumulative H2D payload bytes
    # -- prefix sharing (share_prefix on a paged engine) --
    share_prefix: bool = False
    shared_blocks: int = 0             # blocks currently mapped by >1 slot
    dedupe_hit_blocks: int = 0         # cumulative blocks adopted, not alloc'd
    cow_copies: int = 0                # cumulative copy-on-write forks
    # -- persistent prefix cache (retain_prefix + content-addressed host) --
    retain_prefix: bool = False
    revived_blocks: int = 0            # cached-free blocks re-adopted
    reclaimed_blocks: int = 0          # cached-free blocks taken under pressure
    tail_shared_tokens: int = 0        # rows copied by partial-block tail share
    host_store_blocks: int = 0         # content-addressed host blocks (live)
    host_lru_blocks: int = 0           # ref-0 host blocks awaiting reuse
    host_dedupe_hits: int = 0          # swap-outs resolved by the host store
    host_adopted_blocks: int = 0       # admissions served from the host store
    adopt_in_bytes: int = 0            # cumulative H2D adoption payload bytes
    demoted_blocks: int = 0            # blocks demoted to host on release
    admission_swaps: int = 0           # idle streams swapped to admit prompts
    prefill_fed_tokens: int = 0        # cumulative tokens fed through prefill
    # -- request lifecycle (gateway front door, serving/gateway/) --
    clock: str = "sim"                 # "sim" (SimClock) | "wall" (RealClock)
    modeled_ms: float = 0.0            # shadow modeled time (== sim_ms on sim)
    queue_depth: int = 0               # admitted requests not yet in a session
    rejected_requests: int = 0         # 429s issued at the queue cap
    completed_streams: int = 0
    cancelled_streams: int = 0         # cancel()/client-disconnect teardowns
    # per-stream latency aggregates on the stream time axis (modeled ms
    # under SimClock; under the gateway's RealClock the same fields are
    # the server-side half of the modeled-vs-real cross-check)
    ttft_ms_mean: float = 0.0
    ttft_ms_p50: float = 0.0
    ttft_ms_p95: float = 0.0
    e2e_ms_mean: float = 0.0
    e2e_ms_p50: float = 0.0
    e2e_ms_p95: float = 0.0
    # -- fleet routing (serving/router.py) --
    replicas: int = 1                  # cloud replicas behind the router
    dead_replicas: int = 0             # replicas killed by fault injection
    route_policy: str = ""             # "" = no router in front
    degraded_streams: int = 0          # device-only completions (saturation)
    rerouted_sessions: int = 0         # sessions re-placed after replica death
    affinity_hits: int = 0             # placements that matched a cached prefix
    # -- stall attribution (serving/trace.py; completed streams only) --
    # exclusive buckets summed over completed streams' StreamTimelines;
    # they sum to stall_wall_ms (the summed end-to-end stream time)
    trace: bool = False                # tracer attached to this run
    stall_wall_ms: float = 0.0         # sum of completed streams' e2e time
    stall_device_ms: float = 0.0       # on-device SLM compute
    stall_cloud_ms: float = 0.0        # cloud iterations serving the stream
    stall_link_ms: float = 0.0         # unmasked WAN transfer
    stall_queue_ms: float = 0.0        # admission queueing before prefill
    stall_batch_wait_ms: float = 0.0   # behind other streams' iterations
    stall_swap_ms: float = 0.0         # host-swap transfers on the slot
    stall_preempted_ms: float = 0.0    # serving work a rewind threw away
    stall_other_ms: float = 0.0        # unattributed (tracing off / host)
    # -- latency histograms (Prometheus ladder; gateway /metrics) --
    hist_ttft_ms: dict = field(default_factory=hist_new)
    hist_tpot_ms: dict = field(default_factory=hist_new)
    hist_e2e_ms: dict = field(default_factory=hist_new)

    def as_dict(self) -> dict:
        return asdict(self)


@dataclass
class DeviceSession:
    """One device stream: generation coroutine + cloud client + timing."""
    sid: int
    gen: object                    # generate_steps coroutine
    client: CloudClient
    start_ms: float                # absolute anchor of the device timeline
    state: str = RUNNING
    metrics: object = None         # DeviceMetrics once done
    pending_call: object = None    # CloudCall parked while waiting for slot
    arrival_abs_ms: float = 0.0    # absolute arrival of in-flight verify
    prefill_rid: int | None = None  # in-flight prompt prefill request id
    slots_used: list = field(default_factory=list)
    slo: object = None             # StreamSLO budgets (slo-aware preemption)
    cancelled: bool = False        # torn down via SyneraServer.cancel
    ttft_ms: float | None = None   # stream-relative time of first emit
    e2e_ms: float | None = None    # stream-relative completion time
    n_emitted: int = 0             # output tokens emitted so far
    trace_uid: int = -1            # tracer stream id (-1 when tracing off)
    trace_send_ms: float = 0.0     # absolute send time of in-flight verify

    @property
    def done(self) -> bool:
        return self.state == DONE


class SyneraServer:
    """Owns the cloud side and interleaves N concurrent device sessions."""

    def __init__(self, device: DeviceRuntime, engine: CloudEngine, *,
                 chunk: int = 32, sampling: str = "greedy",
                 latency: CloudLatencyModel | None = None,
                 clock: SimClock | None = None,
                 preempt_policy: str | None = None,
                 clamp_arrivals: bool = False,
                 tracer=None, replica: int = 0):
        self.device = device
        self.engine = engine
        self.sampling = sampling
        self.clock = clock or SimClock()
        # tracing (serving/trace.py): the tracer must live on the same
        # clock, or its timestamps would be on a different axis than the
        # events it records
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.replica = replica
        if self.tracer.enabled and self.tracer.clock is not self.clock:
            raise ValueError("tracer and server must share one clock")
        self.sched = VerificationAwareScheduler(
            engine, chunk=chunk, latency=latency, clock=self.clock,
            preempt_policy=preempt_policy, tracer=self.tracer,
            replica=replica)
        self.sessions: list[DeviceSession] = []
        self._by_req: dict[int, tuple[DeviceSession, str]] = {}
        self._fresh: deque[DeviceSession] = deque()  # opened, not yet run
        self._done_count = 0
        # -- gateway front-door state (serving/gateway/) ----------------
        # clamp_arrivals maps every cloud call's arrival to "now" on the
        # shared clock instead of start_ms + modeled device time: the
        # unpaced wall-clock mode, where requests are served as fast as
        # the host allows and the modeled device timeline is kept only
        # for the modeled-vs-real cross-check.
        self.clamp_arrivals = clamp_arrivals
        self.ext_queue_depth = 0       # gateway-held requests not yet opened
        self.rejected_requests = 0     # gateway 429s at the queue cap

    # ------------------------------------------------------------------
    def open_session(self, prompt, max_new: int, *,
                     arrival_ms: float | None = None,
                     profile_mode: bool = False,
                     slo: object = None,
                     emit=None) -> DeviceSession:
        """Register a new device stream.  ``arrival_ms`` anchors the
        stream's device timeline on the shared clock; default is "now"
        (the stream starts when it is admitted).  ``slo`` optionally
        carries the stream's latency budgets (``swap.StreamSLO``) for
        the slo-aware preemption policy.  ``emit(tokens, t_ms)`` is the
        per-token streaming hook (see ``DeviceRuntime.generate_steps``);
        the server always interposes to record the session's TTFT and
        emitted-token count, then chains to the caller's hook."""
        start = self.clock.now_ms if arrival_ms is None else arrival_ms
        client = CloudClient(self.sched, sampling=self.sampling, slo=slo)
        s = DeviceSession(sid=len(self.sessions), gen=None, client=client,
                          start_ms=start, slo=slo)
        tr = self.tracer
        trace_cb = None
        if tr.enabled:
            s.trace_uid = tr.stream_begin(
                "stream", start, replica=self.replica,
                meta={"sid": s.sid, "replica": self.replica,
                      "prompt_tokens": len(prompt), "max_new": max_new})

            def trace_cb(name, a, b, _tr=tr, _uid=s.trace_uid, _t0=start):
                _tr.stream_child(_uid, name, _t0 + a, _t0 + b)

        def _emit(tokens, t_ms, _s=s, _user=emit, _tr=tr):
            if _s.ttft_ms is None:
                _s.ttft_ms = t_ms
                if _tr.enabled and _s.trace_uid >= 0:
                    _tr.stream_instant(_s.trace_uid, "first_token",
                                       _s.start_ms + t_ms, n=len(tokens))
            elif _tr.enabled and _s.trace_uid >= 0:
                _tr.stream_instant(_s.trace_uid, "emit",
                                   _s.start_ms + t_ms, n=len(tokens))
            _s.n_emitted += len(tokens)
            if _user is not None:
                _user(tokens, t_ms)

        s.gen = self.device.generate_steps(prompt, max_new, use_cloud=True,
                                           profile_mode=profile_mode,
                                           emit=_emit, trace=trace_cb)
        self.sessions.append(s)
        self._fresh.append(s)
        return s

    # ------------------------------------------------------------------
    def _arrival(self, s: DeviceSession, call) -> float:
        """Absolute arrival of a cloud call on the shared clock:
        ``start_ms + modeled device time + uplink``, or "now" in the
        unpaced wall-clock mode (clamp_arrivals)."""
        if self.clamp_arrivals:
            return self.clock.now_ms
        return s.start_ms + call.arrival_ms

    def _submit_verify(self, s: DeviceSession, call) -> None:
        arr = self._arrival(s, call)
        rid = s.client.verify_async(call.seq, call.draft, call.dists,
                                    arrival_ms=arr)
        self._by_req[rid] = (s, "verify")
        s.arrival_abs_ms = arr
        if self.tracer.enabled:
            s.trace_send_ms = s.start_ms + call.send_ms
        s.state = WAIT_CLOUD

    def _advance(self, s: DeviceSession, reply) -> None:
        """Drive one session until it parks on the cloud or finishes."""
        while True:
            try:
                call = s.gen.send(reply)
            except StopIteration as e:
                s.metrics = e.value
                s.e2e_ms = e.value.timeline.t_ms
                s.state = DONE
                self._done_count += 1
                if self.tracer.enabled and s.trace_uid >= 0:
                    tl = e.value.timeline
                    self.tracer.stream_end(
                        s.trace_uid, s.start_ms + tl.t_ms,
                        meta={"wall_ms": tl.t_ms,
                              "tokens": len(e.value.tokens),
                              "buckets": tl.buckets()})
                had_slot = s.client.slot is not None
                s.client.release()
                if s.prefill_rid is not None and not had_slot:
                    # the stream never contacted the cloud again (e.g. no
                    # chunk offloaded): cancel the still-queued prompt
                    # prefill so it cannot later grab — and leak — a slot
                    self.sched.prefill_q = deque(
                        r for r in self.sched.prefill_q
                        if r.req_id != s.prefill_rid)
                    self._by_req.pop(s.prefill_rid, None)
                return
            reply = None
            if call.kind == "prefill":
                rid = s.client.prefill_async(
                    call.prompt, arrival_ms=self._arrival(s, call))
                s.prefill_rid = rid
                self._by_req[rid] = (s, "prefill")
                continue  # fire-and-forget: the device keeps drafting
            if s.client.slot is None:
                # first verify raced ahead of the prompt prefill
                s.pending_call = call
                s.state = WAIT_SLOT
            else:
                self._submit_verify(s, call)
            return

    # ------------------------------------------------------------------
    def cancel(self, session: DeviceSession | int) -> bool:
        """Tear down a mid-flight stream (client disconnect / explicit
        cancellation).  Clean teardown means *nothing leaks*:

        * the generation coroutine is closed (its device cache and
          timeline die with the frame),
        * every queued or in-flight scheduler request the session owns
          is purged *before* its slot is released (a re-assigned slot
          row must never execute a dead stream's work),
        * the slot release returns the row, decrefs/frees its blocks
          (shared prefix blocks survive for their siblings), and drops
          any host-swap state (``release_slot`` -> ``swap.drop``).

        Safe in any state: fresh (never ran), wait_slot (queued prefill
        cancelled), wait_cloud (verify purged), swapped-out, or holding
        shared/CoW blocks.  Returns False if the session was already
        done.  ``DeviceSession.metrics`` stays None for cancelled
        streams (the coroutine frame owns the partial metrics)."""
        s = self.sessions[session] if isinstance(session, int) else session
        if s.done:
            return False
        s.gen.close()
        s.state = DONE
        s.cancelled = True
        if self.tracer.enabled and s.trace_uid >= 0:
            self.tracer.stream_end(s.trace_uid, self.clock.now_ms,
                                   meta={"cancelled": True})
        s.pending_call = None
        self._done_count += 1
        try:
            self._fresh.remove(s)
        except ValueError:
            pass
        rids = {rid for rid, (sess, _) in self._by_req.items() if sess is s}
        for rid in rids:
            self._by_req.pop(rid)
        self.sched.cancel_requests(rids)
        s.client.release()
        return True

    # -- replica-death session migration (serving/router.py) -----------
    def export_session(self, s: DeviceSession):
        """Detach a live session from this (dying) server so the router
        can re-place it on a survivor.  Returns the session's pending
        verify work as a ``CloudCall`` (None for a session that never
        parked on the cloud — e.g. still fresh).

        Unlike :meth:`cancel` nothing is released: a dead replica's pool
        dies with it (``mark_dead`` poisons any further dispatch, and a
        release would be one), and the generation coroutine must stay
        resumable — all device-side state lives in its frame, so the
        stream continues byte-identically once the survivor re-prefills
        its accepted ``seq`` and re-runs the parked verify on top."""
        assert not s.done, "only live sessions are exported"
        rids = {rid for rid, (sess, _) in self._by_req.items() if sess is s}
        pending = None
        if s.pending_call is not None:          # WAIT_SLOT: not yet submitted
            pending, s.pending_call = s.pending_call, None
        else:                                   # WAIT_CLOUD: in the scheduler
            for r in self.sched.export_requests(rids):
                dists = [_SparseDist(idx, val)
                         for idx, val in (r.q_sparse or [])]
                pending = CloudCall("verify", send_ms=0.0, uplink_ms=0.0,
                                    seq=[int(t) for t in r.seq],
                                    draft=[int(t) for t in r.draft],
                                    dists=dists)
        for rid in rids:
            self._by_req.pop(rid, None)
        self.sched.cancel_requests(rids)        # drops any queued prefill
        try:
            self._fresh.remove(s)
        except ValueError:
            pass
        self.sessions.remove(s)
        s.prefill_rid = None
        s.client = None
        return pending

    def import_session(self, s: DeviceSession, pending) -> None:
        """Adopt a session exported from a dead replica.  ``pending`` is
        the ``CloudCall`` :meth:`export_session` returned: its ``seq``
        (the full accepted stream) is re-prefilled from scratch — the
        recompute-eviction restart contract — and the verify is parked
        as the session's pending call, exactly the WAIT_SLOT shape the
        event loop already handles.  When the prefill lands, the verify
        feeds ``seq[frontier:]`` (empty — the prefill covered it) plus
        the draft, and the prefill's retained last row supplies the
        missing verification row; token identity is untouched because
        the re-prefilled KV is position-for-position what incremental
        feeds would have written."""
        s.sid = len(self.sessions)
        self.sessions.append(s)
        s.client = CloudClient(self.sched, sampling=self.sampling, slo=s.slo)
        if pending is None:
            # never reached the cloud: run it like a freshly opened session
            s.state = RUNNING
            self._fresh.append(s)
            return
        now = self.clock.now_ms
        rid = s.client.prefill_async(list(pending.seq), arrival_ms=now)
        s.prefill_rid = rid
        self._by_req[rid] = (s, "prefill")
        # re-anchor the parked call's arrival at "now" on the shared clock
        pending.send_ms = max(0.0, now - s.start_ms)
        pending.uplink_ms = 0.0
        s.pending_call = pending
        s.state = WAIT_SLOT

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One event-loop step: drain runnable sessions, then execute one
        scheduler iteration and deliver its completions.  Returns False
        once every session is done."""
        # Only freshly opened sessions start in `running`; every other
        # transition back to `running` is advanced inline when its event
        # is delivered below, so no full-session scan is needed.
        progressed = bool(self._fresh)
        while self._fresh:
            self._advance(self._fresh.popleft(), None)
        if self._done_count == len(self.sessions):
            return False

        t_before = self.clock.now_ms
        events = self.sched.run_iteration()
        for ev in events:
            entry = self._by_req.pop(ev.req_id, None)
            if entry is None:
                continue
            s, kind = entry
            s.client.on_event(ev)
            if kind == "prefill":
                s.slots_used.append(ev.slot)
                if self.tracer.enabled and s.trace_uid >= 0:
                    self.tracer.stream_instant(s.trace_uid, "slot_assigned",
                                               self.clock.now_ms, n=ev.slot)
                if s.done:
                    # the stream finished before its prefill executed
                    # (cancellation raced the iteration): free the slot
                    s.client.release()
                elif s.pending_call is not None:
                    call, s.pending_call = s.pending_call, None
                    self._submit_verify(s, call)
            else:
                now = self.clock.now_ms
                cloud_ms = now - s.arrival_abs_ms
                cloud_parts = None
                if self.tracer.enabled:
                    # decompose the request's in-flight window for the
                    # device coroutine's stall attribution, and stamp
                    # the round trip on the stream's async track
                    cloud_parts = self.tracer.window_parts(
                        s.arrival_abs_ms, now, replica=self.replica,
                        slot=ev.slot, vrid=ev.req_id,
                        prefill_rid=s.prefill_rid)
                    if s.trace_uid >= 0:
                        self.tracer.stream_child(
                            s.trace_uid, "verify_rt",
                            min(s.trace_send_ms, now), now)
                reply = CloudReply(result=ev.result, cloud_ms=cloud_ms,
                                   fed_tokens=s.client.last_fed_tokens,
                                   cloud_parts=cloud_parts)
                s.state = RUNNING
                self._advance(s, reply)
        if (not events and not progressed
                and self.clock.now_ms == t_before):
            raise RuntimeError(
                "SyneraServer stalled: sessions waiting but no scheduler "
                "event fired and the clock cannot advance")
        return self._done_count < len(self.sessions)

    def run(self) -> list:
        """Drive all open sessions to completion; returns their metrics
        in open order."""
        while self.step():
            pass
        return [s.metrics for s in self.sessions]

    # ------------------------------------------------------------------
    def serve(self, prompts, max_new: int, *,
              concurrency: int | None = None,
              arrivals: list[float] | None = None,
              profile_mode: bool = False,
              slos: list | None = None) -> list:
        """Admission-controlled convenience driver: keep at most
        ``concurrency`` sessions open (None = all at once), optionally
        anchoring each stream at an absolute ``arrivals[i]`` offset
        and attaching per-stream ``slos[i]`` latency budgets.
        Returns per-stream DeviceMetrics in prompt order."""
        if concurrency is not None and concurrency < 1:
            raise ValueError(f"concurrency must be >= 1 or None "
                             f"(unbounded), got {concurrency}")
        first = len(self.sessions)
        idx = 0
        active: list[DeviceSession] = []
        while idx < len(prompts) or active:
            while idx < len(prompts) and (concurrency is None
                                          or len(active) < concurrency):
                arr = None if arrivals is None else arrivals[idx]
                s = self.open_session(prompts[idx], max_new,
                                      arrival_ms=arr,
                                      profile_mode=profile_mode,
                                      slo=None if slos is None
                                      else slos[idx])
                active.append(s)
                idx += 1
            self.step()
            active = [s for s in active if not s.done]
        return [s.metrics for s in self.sessions[first:]]

    # ------------------------------------------------------------------
    def server_stats(self) -> ServerStats:
        """Batching-efficiency counters from the shared scheduler plus
        block-pool utilization from the engine (paged cache)."""
        sched = self.sched
        occ = sched.verify_occupancy
        toks = sched.verify_tokens_fed
        pool = self.engine.pool_stats
        # one count per stream without a slot: sessions parked in
        # wait_slot and owners of still-queued prompt prefills overlap
        # (the queued prefill is what wait_slot waits on)
        waiting_ids = {id(s) for s in self.sessions if s.state == WAIT_SLOT}
        waiting_ids |= {id(self._by_req[r.req_id][0])
                        for r in sched.prefill_q
                        if r.req_id in self._by_req}
        waiting = len(waiting_ids)
        ttfts = [s.ttft_ms for s in self.sessions if s.ttft_ms is not None]
        e2es = [s.e2e_ms for s in self.sessions if s.e2e_ms is not None]
        tpots = [(s.e2e_ms - s.ttft_ms) / (s.n_emitted - 1)
                 for s in self.sessions
                 if s.ttft_ms is not None and s.e2e_ms is not None
                 and s.n_emitted > 1]
        # stall buckets: completed streams' timelines (each sums to its
        # own t_ms, so the totals sum to stall_wall_ms by construction)
        tls = [s.metrics.timeline for s in self.sessions
               if s.done and not s.cancelled and s.metrics is not None]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        return ServerStats(
            trace=self.tracer.enabled,
            stall_wall_ms=sum(t.t_ms for t in tls),
            stall_device_ms=sum(t.compute_ms for t in tls),
            stall_cloud_ms=sum(t.cloud_ms for t in tls),
            stall_link_ms=sum(t.link_ms for t in tls),
            stall_queue_ms=sum(t.queue_ms for t in tls),
            stall_batch_wait_ms=sum(t.batch_wait_ms for t in tls),
            stall_swap_ms=sum(t.swap_ms for t in tls),
            stall_preempted_ms=sum(t.preempted_ms for t in tls),
            stall_other_ms=sum(t.other_ms for t in tls),
            hist_ttft_ms=hist_from(ttfts),
            hist_tpot_ms=hist_from(tpots),
            hist_e2e_ms=hist_from(e2es),
            clock=("wall" if hasattr(self.clock, "modeled_ms") else "sim"),
            modeled_ms=getattr(self.clock, "modeled_ms", self.clock.now_ms),
            queue_depth=self.ext_queue_depth + len(self._fresh) + waiting,
            rejected_requests=self.rejected_requests,
            completed_streams=sum(1 for s in self.sessions
                                  if s.done and not s.cancelled),
            cancelled_streams=sum(1 for s in self.sessions if s.cancelled),
            ttft_ms_mean=float(np.mean(ttfts)) if ttfts else 0.0,
            ttft_ms_p50=pct(ttfts, 50), ttft_ms_p95=pct(ttfts, 95),
            e2e_ms_mean=float(np.mean(e2es)) if e2es else 0.0,
            e2e_ms_p50=pct(e2es, 50), e2e_ms_p95=pct(e2es, 95),
            iterations=sched.iterations,
            prefill_iterations=sched.prefill_iterations,
            verify_iterations=sched.verify_iterations,
            mean_verify_occupancy=sched.mean_verify_occupancy,
            max_verify_occupancy=max(occ) if occ else 0,
            mean_packed_tokens=(sum(toks) / len(toks)) if toks else 0.0,
            sim_ms=self.clock.now_ms,
            waiting_sessions=waiting,
            cache_impl=pool["cache_impl"],
            block_size=pool["block_size"],
            n_blocks=pool["n_blocks"],
            free_blocks=pool["free_blocks"],
            used_blocks=pool["used_blocks"],
            peak_used_blocks=pool["peak_used_blocks"],
            kv_cache_bytes=pool["kv_cache_bytes"],
            kv_bytes_in_use=pool["kv_bytes_in_use"],
            kv_bytes_peak=pool["kv_bytes_peak"],
            preemptions=sched.preemptions,
            preempted_refed_tokens=sched.preempted_refed_tokens,
            preempt_policy=sched.preempt_policy,
            swap=pool["swap"],
            recompute_evictions=sched.recompute_evictions,
            swap_evictions=sched.swap_evictions,
            swap_expirations=sched.swap_expirations,
            swapped_blocks=pool["swapped_blocks"],
            swap_out_bytes=pool["swap_out_bytes"],
            swap_in_bytes=pool["swap_in_bytes"],
            share_prefix=pool["share_prefix"],
            shared_blocks=pool["shared_blocks"],
            dedupe_hit_blocks=pool["dedupe_hit_blocks"],
            cow_copies=pool["cow_copies"],
            cached_free_blocks=pool["cached_free_blocks"],
            retain_prefix=pool["retain_prefix"],
            revived_blocks=pool["revived_blocks"],
            reclaimed_blocks=pool["reclaimed_blocks"],
            tail_shared_tokens=pool["tail_shared_tokens"],
            host_store_blocks=pool["host_store_blocks"],
            host_lru_blocks=pool["host_lru_blocks"],
            host_dedupe_hits=pool["host_dedupe_hits"],
            host_adopted_blocks=pool["host_adopted_blocks"],
            adopt_in_bytes=pool["adopt_in_bytes"],
            demoted_blocks=pool["demoted_blocks"],
            admission_swaps=sched.admission_swaps,
            prefill_fed_tokens=sched.prefill_fed_tokens,
        )

    def stats(self) -> dict:
        """Dict view of :meth:`server_stats` (the stable extras schema)."""
        return self.server_stats().as_dict()


# ---------------------------------------------------------------------------
# Fleet composition (serving/router.py)
# ---------------------------------------------------------------------------

def build_fleet(device: DeviceRuntime, engines, *, chunk: int = 32,
                sampling: str = "greedy",
                latency: CloudLatencyModel | None = None,
                clock: SimClock | None = None,
                preempt_policy: str | None = None,
                clamp_arrivals: bool = False,
                tracer=None) -> list[SyneraServer]:
    """Compose one ``SyneraServer`` per engine on a single shared clock.

    Each replica is fully independent on the cloud side — its own block
    pool, prefix index and swap tier — but the fleet shares one time
    axis (cross-replica latency numbers must be comparable and a
    session re-placed after a replica death keeps its anchor) and one
    ``DeviceRuntime``: all device-side session state lives in each
    generation coroutine's frame, so a single set of device weights
    backs every stream regardless of which replica verifies it."""
    clock = clock or SimClock()
    return [SyneraServer(device, eng, chunk=chunk, sampling=sampling,
                         latency=latency, clock=clock,
                         preempt_policy=preempt_policy,
                         clamp_arrivals=clamp_arrivals,
                         tracer=tracer, replica=i)
            for i, eng in enumerate(engines)]


# how per-replica ServerStats fields combine into one fleet view: maxed
# (shared clock / peak concurrency / layout constants), or'd (feature
# flags), or taken from replica 0 (homogeneous config strings); every
# other numeric field is a counter or gauge and sums
_AGG_MAX = {"sim_ms", "modeled_ms", "max_verify_occupancy", "block_size"}
_AGG_OR = {"swap", "share_prefix", "retain_prefix", "trace"}
_AGG_FIRST = {"clock", "preempt_policy", "route_policy"}


def aggregate_server_stats(per_replica: list[ServerStats], *,
                           ttfts=None, e2es=None) -> ServerStats:
    """Fold per-replica :class:`ServerStats` into one fleet-wide view.

    Counters and gauges sum (a fleet's pool is the union of its pools);
    occupancy means re-weight by each replica's verify iterations; the
    latency percentiles are recomputed from the pooled per-stream
    samples the caller passes in (``ttfts`` / ``e2es``) — percentiles
    of percentiles would be meaningless."""
    dicts = [s.as_dict() for s in per_replica]
    wsum = sum(d["verify_iterations"] for d in dicts) or 1
    out = {}
    for k in dicts[0]:
        vals = [d[k] for d in dicts]
        if k in ("mean_verify_occupancy", "mean_packed_tokens"):
            out[k] = sum(v * d["verify_iterations"]
                         for v, d in zip(vals, dicts)) / wsum
        elif k == "cache_impl":
            out[k] = ("paged" if any(v == "paged" for v in vals)
                      else vals[0])
        elif k in _AGG_FIRST:
            out[k] = vals[0]
        elif k in _AGG_OR:
            out[k] = any(vals)
        elif k in _AGG_MAX:
            out[k] = max(vals)
        elif k.startswith("hist_"):
            out[k] = hist_merge(vals)  # cumulative counts fold elementwise
        elif k.startswith("ttft_") or k.startswith("e2e_"):
            out[k] = 0.0
        else:
            out[k] = sum(vals)

    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else 0.0

    for name, xs in (("ttft", list(ttfts or [])), ("e2e", list(e2es or []))):
        out[f"{name}_ms_mean"] = float(np.mean(xs)) if xs else 0.0
        out[f"{name}_ms_p50"] = pct(xs, 50)
        out[f"{name}_ms_p95"] = pct(xs, 95)
    return ServerStats(**out)
