"""Device<->cloud WAN link and latency/energy models.

The container is CPU-only, so wall-clock numbers for the Jetson/Pixel
device and the A6000 cloud of the paper are *modeled* with calibrated
constants (paper §6: SLM TBT tens of ms on Jetson; LLM verification
~100-400 ms; bandwidths 0.1-100 Mbps).  Transfer *sizes* are computed
exactly from the real payloads (tokens + compressed distributions), which
is what the paper's bandwidth study (Fig 13) measures.

Token streams themselves are produced by the real models; only time is
simulated.  See DESIGN.md §2.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class SimClock:
    """Shared discrete-event clock (absolute simulated milliseconds).

    One instance is shared by the ``SyneraServer`` event loop and the
    ``VerificationAwareScheduler`` so that device-stream arrival times
    and cloud iteration costs live on a single time axis: the scheduler
    fast-forwards to the next request arrival when idle and advances by
    iteration cost when busy, so per-stream round-trip times measured
    against this clock include real cross-stream queueing.
    """
    now_ms: float = 0.0

    def advance(self, dt_ms: float) -> float:
        self.now_ms += dt_ms
        return self.now_ms

    def advance_to(self, t_ms: float) -> float:
        """Fast-forward (never rewind) to an absolute time."""
        self.now_ms = max(self.now_ms, t_ms)
        return self.now_ms


@dataclass
class RealClock:
    """Wall-clock drop-in for :class:`SimClock` (serving/gateway).

    ``now_ms`` reads the monotonic clock, so arrival gating, queueing
    delays and completion times measured against this clock are *real*
    — the axis a network client experiences.  The modeled costs the
    scheduler charges via :meth:`advance` / :meth:`advance_to` do not
    move real time; instead they accumulate into ``modeled_ms`` with
    SimClock semantics (advance adds, advance_to fast-forwards), a
    shadow of where the simulated clock would stand on the same
    schedule.  Comparing ``now_ms`` against ``modeled_ms`` at any point
    is the modeled-vs-real cross-check: the gap is work the latency
    model does not account for (real compute, GC, socket overhead).

    ``pace=True`` additionally *sleeps* through modeled costs and idle
    fast-forwards, so cloud events land at roughly their modeled wall
    times (real >= modeled; the excess is host compute).  Long idle
    waits sleep in bounded slices and may return early — callers
    (scheduler/server loops) re-invoke until the clock catches up, so
    cancellation stays responsive.
    """
    pace: bool = False
    max_sleep_ms: float = 50.0     # per-call sleep slice (pace mode)
    modeled_ms: float = 0.0        # shadow SimClock on the same schedule
    _t0: float = field(default_factory=time.monotonic)

    @property
    def now_ms(self) -> float:
        return (time.monotonic() - self._t0) * 1e3

    def advance(self, dt_ms: float) -> float:
        self.modeled_ms += dt_ms
        if self.pace and dt_ms > 0:
            time.sleep(dt_ms / 1e3)
        return self.now_ms

    def advance_to(self, t_ms: float) -> float:
        """Fast-forward the modeled shadow; real time cannot jump.  In
        pace mode, sleep toward ``t_ms`` (one bounded slice)."""
        self.modeled_ms = max(self.modeled_ms, t_ms)
        if self.pace:
            wait = min(t_ms - self.now_ms, self.max_sleep_ms)
            if wait > 0:
                time.sleep(wait / 1e3)
        return self.now_ms


@dataclass
class LinkModel:
    bandwidth_mbps: float = 10.0
    rtt_ms: float = 20.0

    def transfer_ms(self, nbytes: int) -> float:
        bits = nbytes * 8.0
        return self.rtt_ms / 2.0 + bits / (self.bandwidth_mbps * 1e6) * 1e3


@dataclass
class DeviceLatencyModel:
    """Per-token SLM compute on the device (Jetson AGX Orin class)."""
    ms_per_token: float = 30.0          # full-depth forward
    ms_fixed: float = 2.0               # dispatch overhead per forward
    energy_j_per_token: float = 1.86    # paper Table 5, edge-centric
    scheduling_ms_per_token: float = 0.4  # paper Table 5: <0.5ms

    def draft_ms(self, n_tokens: int, layer_frac: float = 1.0) -> float:
        """layer_frac < 1 models layer-wise early exit savings."""
        return self.ms_fixed + n_tokens * self.ms_per_token * layer_frac

    def energy_j(self, n_tokens: int, layer_frac: float = 1.0) -> float:
        return n_tokens * self.energy_j_per_token * layer_frac


@dataclass
class CloudLatencyModel:
    """Cloud engine iteration cost (A6000-class, continuous batching).

    ms_base calibrated to a 13B bf16 verifier on A6000: the decode/verify
    iteration floor is the weight stream (~26 GB / ~650 GB/s ~ 40 ms),
    amortized across the batched slots of one iteration.

    ``host_link_gbps`` models the accelerator->host interconnect the
    scheduler's verifier state crosses every iteration (PCIe-class, GB/s;
    effective D2H with sync overheads).  The CPU container aliases
    device/host memory (np.asarray is zero-copy), so this term is what
    makes the engine's measured ``bytes_to_host`` show up in modeled
    serving time the way it would on real hardware — the pre-change
    full-vocab logits round trip (e.g. 8 slots x 32 chunk x 128k vocab
    x 4B = 128 MiB/iter) costs ~21 ms here, the fused rows microseconds.
    """
    ms_base: float = 40.0               # per-iteration fixed cost
    ms_per_token: float = 0.12          # per (token x slot) in the batch
    ms_scheduler: float = 0.5           # verification-aware scheduling overhead
    prefill_ms_per_token: float = 0.25
    host_link_gbps: float = 6.0         # effective D2H bandwidth (GB/s)

    def iteration_ms(self, total_tokens: int) -> float:
        return self.ms_base + self.ms_scheduler + total_tokens * self.ms_per_token

    def prefill_ms(self, total_tokens: int) -> float:
        return self.ms_base + total_tokens * self.prefill_ms_per_token

    def host_transfer_ms(self, nbytes: int) -> float:
        return nbytes / (self.host_link_gbps * 1e9) * 1e3

    # -- swap-vs-recompute disposition (serving/swap.py) ----------------
    def swap_roundtrip_ms(self, nbytes: int) -> float:
        """Modeled cost of evicting a stream to host memory and later
        restoring it: the D2H gather plus the H2D scatter, both charged
        through ``host_link_gbps`` on the measured block bytes."""
        return 2.0 * self.host_transfer_ms(nbytes)

    def refeed_ms(self, n_tokens: int, chunk: int) -> float:
        """Modeled cost of recompute-eviction: the victim's accepted
        prefix re-feeds as from-scratch partial prefills, i.e. about
        ``ceil(n/chunk)`` extra verify iterations' fixed cost plus the
        per-token compute."""
        n_iters = -(-max(int(n_tokens), 0) // max(int(chunk), 1))
        return (n_iters * (self.ms_base + self.ms_scheduler)
                + n_tokens * self.ms_per_token)


@dataclass
class CostModel:
    """Estimated cloud serving cost (paper §6.1): c = (1/Pf) * T * W.

    Pf = packing factor (Table 3), T = average TBT, W = fraction of tokens
    that hit the cloud."""
    packing_factor: float = 13.0   # Llama-7B-class verifier

    def cost(self, avg_tbt_ms: float, cloud_token_frac: float) -> float:
        return (1.0 / self.packing_factor) * avg_tbt_ms * cloud_token_frac


# Back-compat alias: the per-stream timeline moved to serving/trace.py,
# where it gained exclusive stall-attribution buckets in place of the
# old unstructured ``events`` tuple list.
from repro.serving.trace import StreamTimeline as Timeline  # noqa: E402
