"""Device-side SLM runtime (Synera §4.2-§4.4).

Runs the on-device SLM with:
  * per-step confidence + importance extraction (naive attention path or
    the fused Pallas kernel on TPU),
  * layer-wise early exit (margin over the last 25% of layers) — on this
    CPU container all layers execute and the exit *decision* feeds the
    latency/energy model (DESIGN.md §2),
  * draft chunking (gamma tokens) + selective offload decisions,
  * compression of the transmitted distributions,
  * stall-free parallel inference (rejection-position prediction + PI).

The generation loop is a resumable coroutine (``generate_steps``) that
yields ``CloudCall`` requests and is resumed with ``CloudReply``
responses, so a ``SyneraServer`` can interleave many concurrent streams
over one cloud engine; ``generate`` is the blocking single-stream
driver over it.

Position bookkeeping invariant: ``seq`` is the accepted token stream
(prompt + output).  At the top of every loop iteration, positions
0..len(seq)-2 are in the device cache and ``seq[-1]`` is not yet fed.
Drafting feeds ``seq[-1]`` at position len(seq)-1 and autoregressively
produces gamma draft tokens.  After a rejection, stale draft KV beyond
the accepted frontier is masked by causality until overwritten (the same
argument as the cloud scheduler's).

The SLM must be a dense decoder (the paper's SLMs are Llama-family).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compression as CP
from repro.core import early_exit as EE
from repro.core import parallel as PI
from repro.core.offload import OffloadPolicy
from repro.core.profiling import ChunkRecord
from repro.models import layers as L
from repro.models import model as M
from repro.serving.link import DeviceLatencyModel, LinkModel
from repro.serving.trace import StreamTimeline as Timeline


@dataclass
class CloudCall:
    """A cloud request emitted by the device generation coroutine.

    ``send_ms`` is the *device-stream-relative* time the payload leaves
    the device; the serving layer maps it onto the shared absolute clock
    (``session.start_ms + send_ms``).  ``arrival_ms`` (still stream
    relative) adds the uplink transfer.
    """
    kind: str                     # "prefill" | "verify"
    send_ms: float
    uplink_ms: float
    prompt: list | None = None    # prefill
    seq: list | None = None       # verify: accepted stream (prompt+output)
    draft: list | None = None     # verify: pending draft tokens
    dists: list | None = None     # verify: compressed SLM dists

    @property
    def arrival_ms(self) -> float:
        return self.send_ms + self.uplink_ms


@dataclass
class CloudReply:
    """Response delivered back into the coroutine for a verify call.

    ``cloud_ms`` is time spent at the cloud from request arrival to
    completion — queueing behind other streams *plus* compute, as
    measured on the shared clock.
    """
    result: object = None         # VerifyResult
    cloud_ms: float = 0.0
    fed_tokens: int = 0           # tokens this request fed the cloud LLM
    # chronological (category, ms) decomposition of the request's
    # in-flight window at the cloud (Tracer.window_parts); None when
    # tracing is off — the stall then lands in the "other" bucket
    cloud_parts: list | None = None


@dataclass
class DeviceMetrics:
    tokens: list = field(default_factory=list)
    n_chunks: int = 0
    n_offloaded: int = 0
    n_draft_tokens: int = 0
    n_accepted_tokens: int = 0
    n_cloud_tokens: int = 0        # tokens emitted via cloud verification
    n_cloud_fed_tokens: int = 0    # tokens forwarded through the cloud LLM
    n_local_tokens: int = 0
    pi_position_hits: int = 0
    pi_adopted: int = 0
    pi_attempts: int = 0
    layers_saved_frac: list = field(default_factory=list)
    uplink_bytes: int = 0
    downlink_bytes: int = 0
    chunk_records: list = field(default_factory=list)
    timeline: Timeline = field(default_factory=Timeline)

    @property
    def offload_rate(self) -> float:
        return self.n_offloaded / max(self.n_chunks, 1)

    @property
    def acceptance_rate(self) -> float:
        return self.n_accepted_tokens / max(self.n_draft_tokens, 1)

    @property
    def tbt_ms(self) -> float:
        return self.timeline.t_ms / max(len(self.tokens), 1)

    @property
    def cloud_token_frac(self) -> float:
        return self.n_cloud_tokens / max(len(self.tokens), 1)

    @property
    def mean_layers_saved(self) -> float:
        return float(np.mean(self.layers_saved_frac)) if self.layers_saved_frac else 0.0


def _make_device_step(cfg):
    """jit-able single-token step returning per-layer last-position logits
    (for early exit), mean importance over the cache, and the new cache."""

    def step(params, cache, token, pos):
        h = jnp.take(params["embed"], token, axis=0)  # (1, 1, d)

        def body(hh, xs):
            lp, lc = xs
            hn, nc, imp, _ = M._layer(cfg, lp, hh, pos, lc, ret_imp=True)
            return hn, (nc, imp, hn[:, -1])

        _, (ncache, imps, h_layers) = lax.scan(
            body, h, (params["layers"], cache["layers"]))
        hl = L.rms_norm(h_layers, params["final_norm"], cfg.norm_eps)  # (L,1,d)
        unemb = (params["embed"].T if cfg.tie_embeddings
                 else params["unembed"])
        layer_logits = (hl @ unemb)[:, 0]           # (L, V)
        imp_mean = imps.mean(axis=0)[0]             # (S,) over cache slots
        return layer_logits, imp_mean, {"layers": ncache}

    return step


class DeviceRuntime:
    def __init__(self, cfg, params, *, s_max: int = 512, gamma: int = 4,
                 policy: OffloadPolicy | None = None,
                 ee: EE.EarlyExitConfig | None = None,
                 sampling: str = "greedy", comp_top_k: int = 8,
                 latency: DeviceLatencyModel | None = None,
                 link: LinkModel | None = None, seed: int = 0,
                 use_early_exit: bool = True, use_pi: bool = True,
                 use_compression: bool = True, alpha: float = 0.7,
                 wire_vocab: int = 0):
        assert cfg.family == "dense", "device SLM must be a dense decoder"
        # importance extraction needs the attention matrix (naive) or the
        # fused attn_importance Pallas kernel; anything else maps to naive
        impl = "pallas" if cfg.attn_impl == "pallas" else "naive"
        # the device cache is a single short dense buffer (batch=1); paging
        # is a cloud-engine concern — force the dense layout here so the
        # importance slot math (pos % s_max) stays valid
        self.cfg = cfg.replace(attn_impl=impl, remat=False,
                               cache_impl="dense")
        self.params = params
        self.s_max = s_max
        self.gamma = gamma
        self.policy = policy or OffloadPolicy()
        self.ee = ee or EE.EarlyExitConfig()
        self.sampling = sampling
        self.comp_top_k = comp_top_k
        self.latency = latency or DeviceLatencyModel()
        self.link = link or LinkModel()
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.use_early_exit = use_early_exit
        self.use_pi = use_pi
        self.use_compression = use_compression
        self.alpha = alpha
        # Payload accounting vocab: the experiments use a tiny task vocab,
        # but the WAN transfer sizes of the paper (Fig 13) are set by a
        # production vocab (32,000 for Llama-2).  ``wire_vocab`` sizes the
        # *uncompressed* distribution payload accordingly; the compressed
        # payload only depends on the sampling support (top-k), so this
        # affects exactly what it should.
        self.wire_vocab = wire_vocab or self.cfg.vocab

        self._step = jax.jit(_make_device_step(self.cfg))
        self._prefill = jax.jit(
            lambda p, c, t, pos: M.forward(self.cfg, p, t, pos, cache=c)[:2])

    # ------------------------------------------------------------------
    def _sample(self, logits: np.ndarray) -> int:
        if self.sampling == "greedy":
            return int(np.argmax(logits))
        c = CP.compress(logits, method="top_k", k=self.comp_top_k)
        p = c.val.astype(np.float64)
        return int(self.rng.choice(c.idx, p=p / p.sum()))

    def _one_token(self, cache, token: int, pos: int, m: DeviceMetrics):
        """Feed `token` at `pos`; returns (logits, conf, imp_vec, cache)."""
        tk = jnp.asarray([[token]], jnp.int32)
        ps = jnp.asarray([[pos]], jnp.int32)
        layer_logits, imp_vec, cache = self._step(self.params, cache, tk, ps)
        layer_logits = np.asarray(layer_logits, np.float32)  # (L, V)
        nL = layer_logits.shape[0]
        if self.use_early_exit:
            exit_layer, _, _ = EE.pick_exit_layer(
                jnp.asarray(layer_logits)[:, None, :], nL, self.ee)
            el = int(exit_layer[0])
            logits = layer_logits[el]
            frac_saved = (nL - 1 - el) / nL
        else:
            logits = layer_logits[-1]
            frac_saved = 0.0
        m.layers_saved_frac.append(frac_saved)
        m.timeline.advance(self.latency.draft_ms(1, 1.0 - frac_saved),
                           "compute")
        m.timeline.energy_j += self.latency.energy_j(1, 1.0 - frac_saved)
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        conf = float(probs.max())
        return logits, conf, np.asarray(imp_vec, np.float32), cache

    def _draft_chunk(self, cache, first_token: int, start_pos: int,
                     m: DeviceMetrics):
        """Feed `first_token` at `start_pos` and draft gamma tokens.

        Draft token d_j (1-indexed) gets fed at position start_pos + j;
        its importance accumulates the attention later in-chunk queries
        (including itself) pay to its key.
        Returns (tokens [d_1..d_g], logits_list, confs, imp (g,), cache).
        """
        tokens, logits_list, confs = [], [], []
        imp_acc = np.zeros(self.gamma, np.float64)
        tok, pos = first_token, start_pos
        for j in range(self.gamma):
            logits, conf, imp_vec, cache = self._one_token(cache, tok, pos, m)
            nxt = self._sample(logits)
            tokens.append(nxt)
            logits_list.append(logits)
            confs.append(conf)
            for jj in range(1, j + 1):   # keys of d_1..d_j are in cache
                slot = (start_pos + jj) % self.s_max
                imp_acc[jj - 1] += float(imp_vec[slot])
            tok, pos = nxt, pos + 1
        return tokens, logits_list, confs, imp_acc / self.gamma, cache

    # ------------------------------------------------------------------
    def generate(self, prompt: list[int], max_new: int, cloud=None,
                 profile_mode: bool = False) -> DeviceMetrics:
        """Generate up to ``max_new`` tokens after the prompt (blocking).

        ``cloud`` implements the CloudClient protocol (serving/synergy.py)
        or None for edge-centric generation.  profile_mode offloads every
        chunk and records ChunkRecords for offline profiling (§5).

        This is a thin synchronous driver over :meth:`generate_steps`;
        multi-tenant serving drives the coroutine directly through
        ``SyneraServer`` so device compute from many streams interleaves
        with shared cloud iterations.
        """
        gen = self.generate_steps(prompt, max_new,
                                  use_cloud=cloud is not None,
                                  profile_mode=profile_mode)
        reply = None
        while True:
            try:
                call = gen.send(reply)
            except StopIteration as e:
                return e.value
            if call.kind == "prefill":
                cloud.prefill(call.prompt, arrival_ms=call.arrival_ms)
                reply = None
            else:
                result, cloud_ms = cloud.verify(
                    seq=call.seq, draft=call.draft, dists=call.dists,
                    arrival_ms=call.arrival_ms)
                reply = CloudReply(result=result, cloud_ms=cloud_ms,
                                   fed_tokens=cloud.last_fed_tokens)

    def generate_steps(self, prompt: list[int], max_new: int, *,
                       use_cloud: bool = True, profile_mode: bool = False,
                       emit=None, trace=None):
        """Device generation as a resumable coroutine.

        Yields a :class:`CloudCall` whenever the stream needs the cloud;
        the driver resumes it with ``None`` for fire-and-forget prefill
        notifications and with a :class:`CloudReply` carrying the
        ``VerifyResult`` for verify calls.  Returns (via StopIteration)
        the stream's :class:`DeviceMetrics`.

        ``emit(tokens, t_ms)`` is the incremental-output hook (token
        streaming): it fires each time accepted output tokens are
        appended to the stream — a locally kept draft chunk or the
        verified tokens of a cloud round trip — with the new tokens
        (clipped to ``max_new``) and the stream-relative device time.
        ``seq`` only ever grows (rejected drafts never enter it), so
        emitted tokens are final: their concatenation is byte-identical
        to the returned ``DeviceMetrics.tokens``.

        ``trace(name, t0_ms, t1_ms)`` is the optional tracing hook
        (serving/trace.py): it receives stream-relative device-side
        spans — ``draft`` (SLM compute), ``pi_overlap`` (speculation
        masking a round trip), ``stall`` (unmasked round-trip tail).
        Tracing is passive; timings and tokens are identical with it on
        or off.

        All device-side state (KV cache, accepted stream, timeline) lives
        in this generator's frame, so one ``DeviceRuntime`` (weights +
        jitted steps) can back arbitrarily many concurrent sessions.
        """
        m = DeviceMetrics()
        cache = M.init_cache(self.cfg, 1, self.s_max)
        prompt = [int(t) for t in prompt]
        T = len(prompt)
        assert T >= 2, "need at least 2 prompt tokens"
        max_len = max_new
        # dedicated offload-decision stream, deterministic per prompt:
        # ablation variants (PI on/off, EE on/off) then share identical
        # offload decisions, so quality differences isolate the mechanism
        # under test (PI is exactness-preserving; only latency may move)
        rng_off = np.random.default_rng(
            self.seed * 1000003 + sum(prompt) + 31 * T)

        # Feed prompt[:-1] so the invariant holds with seq = prompt: the
        # first generated token is itself a draft token (SLM-centric
        # generation; *every* output token is subject to verification).
        tk = jnp.asarray([prompt[:-1]], jnp.int32)
        pos = jnp.asarray([np.arange(T - 1)], jnp.int32)
        _, cache = self._prefill(self.params, cache, tk, pos)
        m.timeline.advance(self.latency.draft_ms(T - 1, 1.0), "compute")
        m.timeline.energy_j += self.latency.energy_j(T - 1, 1.0)
        t_mark = m.timeline.t_ms   # device time already emitted as spans
        if trace is not None:
            trace("prompt_feed", 0.0, t_mark)

        if use_cloud:
            up = 4 * T + 32
            m.uplink_bytes += up
            dt = self.link.transfer_ms(up)
            # fire-and-forget: cloud prefill overlaps device drafting; the
            # scheduler serializes it before this stream's first verify
            yield CloudCall("prefill", send_ms=m.timeline.t_ms,
                            uplink_ms=dt, prompt=prompt)

        seq = list(prompt)     # invariant: seq[:-1] fed, seq[-1] not fed
        pi_chunk = None
        n_emitted = 0

        def _flush_emit():
            nonlocal n_emitted
            if emit is None:
                return
            vis = min(len(seq) - T, max_new)
            if vis > n_emitted:
                emit(seq[T + n_emitted:T + vis], m.timeline.t_ms)
                n_emitted = vis

        while len(seq) - T < max_new:
            if pi_chunk is not None:
                tokens, logits_list, confs, imp, cache = pi_chunk
                pi_chunk = None
            else:
                tokens, logits_list, confs, imp, cache = self._draft_chunk(
                    cache, seq[-1], len(seq) - 1, m)
            m.n_chunks += 1
            mean_conf = float(np.mean(confs))
            mean_imp = float(np.mean(imp))

            do_offload = use_cloud
            if do_offload and not profile_mode:
                do_offload = self.policy.should_offload(
                    rng_off, mean_conf, mean_imp,
                    seq_pos=len(seq) - T, max_len=max_len,
                    seq_exit_frac=(self.ee.seq_exit_frac
                                   if self.use_early_exit else 0.0),
                    chunk_index=m.n_chunks - 1)

            if not do_offload:
                seq.extend(tokens)
                m.n_local_tokens += len(tokens)
                _flush_emit()
                continue

            # ---- offload: build + send the verification request --------
            m.n_offloaded += 1
            m.n_draft_tokens += len(tokens)  # drafts actually verified
            dists = [CP.compress(
                lg, method=("greedy" if self.sampling == "greedy"
                            else "top_k"), k=self.comp_top_k)
                for lg in logits_list]
            payload = CP.chunk_payload_bytes(
                dists, len(tokens), compressed=self.use_compression,
                vocab=self.wire_vocab)
            m.uplink_bytes += payload
            uplink_ms = self.link.transfer_ms(payload)

            # ---- stall-free parallel inference (during the round trip) --
            # Position note: before this chunk len(seq) = n; drafting fed
            # seq[-1]@n-1 and d_1..d_{gamma-1}@n..n+gamma-2.  d_gamma
            # (position n+gamma-1) is NOT yet in the cache.
            draft_base = len(seq)          # d_j sits at draft_base + j - 1
            pi_state = None
            dgamma_fed = False
            overlap_t0 = m.timeline.t_ms
            if self.use_pi and not profile_mode:
                m.pi_attempts += 1
                r_star = PI.predict_rejection(np.asarray(confs), self.alpha,
                                              self.rng)
                if r_star < self.gamma:
                    c3 = CP.compress(logits_list[r_star], method="top_k", k=3)
                    alt = PI.choose_alternative(c3.idx, c3.val,
                                                tokens[r_star], self.rng)
                    # d_1..d_{r*} already fed; alt replaces d_{r*+1}
                    spec = self._draft_chunk(cache, alt,
                                             draft_base + r_star, m)
                else:
                    # predicted full acceptance: SLM predicts the bonus
                    logits_b, _, _, cache = self._one_token(
                        cache, tokens[-1], draft_base + self.gamma - 1, m)
                    dgamma_fed = True
                    alt = self._sample(logits_b)
                    spec = self._draft_chunk(cache, alt,
                                             draft_base + self.gamma, m)
                pi_state = PI.PIState(r_star=r_star, alt_token=alt,
                                      tokens=spec)
            overlap_ms = m.timeline.t_ms - overlap_t0
            if trace is not None:
                if overlap_t0 > t_mark:
                    trace("draft", t_mark, overlap_t0)
                if overlap_ms > 0.0:
                    trace("pi_overlap", overlap_t0, m.timeline.t_ms)

            # ---- cloud round trip ---------------------------------------
            reply = yield CloudCall("verify", send_ms=overlap_t0,
                                    uplink_ms=uplink_ms,
                                    seq=list(seq), draft=list(tokens),
                                    dists=dists)
            result, cloud_ms = reply.result, reply.cloud_ms
            m.n_cloud_fed_tokens += reply.fed_tokens
            down_bytes = 32 + 4 * (len(result.tokens) + 1)
            m.downlink_bytes += down_bytes
            down_ms = self.link.transfer_ms(down_bytes)
            rtt_ms = uplink_ms + cloud_ms + down_ms

            # PI compute overlapped with the round trip; only the excess
            # round-trip time stalls the pipeline (Fig 6).
            stall_ms = max(rtt_ms - overlap_ms, 0.0)
            if trace is not None and stall_ms > 0.0:
                trace("stall", m.timeline.t_ms, m.timeline.t_ms + stall_ms)
            m.timeline.advance_stall(stall_ms, uplink_ms, reply.cloud_parts,
                                     down_ms, overlap_ms)
            m.timeline.comm_ms += min(rtt_ms, overlap_ms)  # masked comm
            t_mark = m.timeline.t_ms

            n_acc = result.n_accepted
            verified = list(result.tokens)  # accepted prefix + corrected/bonus
            seq.extend(verified)
            m.n_cloud_tokens += len(verified)
            m.n_accepted_tokens += n_acc
            _flush_emit()

            if n_acc >= self.gamma and not dgamma_fed:
                # full acceptance: d_gamma entered `seq` but was never fed
                # during drafting — feed it so the cache covers seq[:-1]
                _, _, _, cache = self._one_token(
                    cache, tokens[-1], draft_base + self.gamma - 1, m)

            if profile_mode:
                m.chunk_records.append(ChunkRecord(
                    mean_conf=mean_conf, mean_imp=mean_imp,
                    n_accepted=n_acc, gamma=self.gamma))

            if pi_state is not None:
                adopt, pos_hit = PI.merge(pi_state, n_acc, verified[-1],
                                          self.gamma)
                m.pi_position_hits += int(pos_hit)
                m.pi_adopted += int(adopt)
                if adopt:
                    # the speculative chunk is the next draft chunk; the
                    # cache already covers seq[-1]
                    pi_chunk = pi_state.tokens
            # on non-adoption, stale speculative KV beyond len(seq)-1 is
            # causally masked until overwritten — nothing to roll back.

        m.tokens = seq[T:T + max_new]
        _flush_emit()
        if trace is not None and m.timeline.t_ms > t_mark:
            trace("draft", t_mark, m.timeline.t_ms)
        return m

    # ------------------------------------------------------------------
    def perplexity(self, tokens: list[int]) -> float:
        """Prompt perplexity under the SLM (EdgeFM-LLM baseline input-level
        offload signal)."""
        tk = jnp.asarray([tokens], jnp.int32)
        pos = M.default_positions(1, len(tokens))
        logits, _, _, _ = M.forward(self.cfg, self.params, tk, pos)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, tk[:, 1:, None], axis=-1).mean()
        return float(jnp.exp(nll))
