"""Verification-aware scheduler (Synera §4.5, Algorithm 1).

Batching policy over the CloudEngine:

* Prefill requests are prioritized: while any are queued *and a slot is
  free*, an iteration executes a prefill batch (lines 5-11 of
  Algorithm 1).  When prefills are queued but no slot is free, the
  iteration falls through to verification work — completing
  verifications is what eventually releases slots, so stalling here
  would deadlock the head of the line.
* Otherwise, queued verification requests are batched.  Each request is
  a *partial prefill*: device-accepted-but-uncached tokens followed by
  pending-verify draft tokens, executed over the slot's cached prefix.
  Requests are segmented into fixed-size chunks (Sarathi-style, default
  32) so iterations stay uniform (lines 12-21).  One iteration packs at
  most one chunk per slot but chunks from *many* slots — this is where
  multi-tenant batching happens.
* When a request's last chunk completes, the draft tokens are verified
  ("draft & verify") and the result is emitted.

Device residency (the perf contract, docs/serving_api.md): by default
(``fused=True``) the scheduler consumes the engine's fused rows —
per-row argmax ids, the gathered probability of each known next token
(the scheduler passes a ``targets`` plane alongside tokens/positions),
and top-k compressed sampling support — so no full-vocab tensor crosses
the host boundary per verify iteration and requests retain only O(gamma
* K) host state.  ``fused=False`` keeps the pre-fusion host-numpy path
(full (slots, chunk, V) logits round trip + numpy verifier) for
benchmarking and identity testing; both modes emit byte-identical
greedy token streams.

Time: the scheduler shares a ``SimClock`` (serving/link.py) with
whoever drives it (the ``SyneraServer`` event loop, or a private clock
for the legacy blocking facade).  Requests carry an absolute
``arrival_ms``; a request is only admitted into an iteration once the
clock has reached its arrival.  When the scheduler is idle it
fast-forwards to the earliest queued arrival, and when it executes a
batch it advances the clock by the iteration's modeled cost — so
completion times measured on this clock reflect genuine queueing behind
other streams' work, not a per-request private accumulator.

Memory pressure (paged engines): verify iterations reserve their block
growth and, when the pool runs dry, evict a victim chosen by the
pluggable policy (serving/swap.py: youngest | most-blocks | slo-aware)
with a per-victim disposition — host-swap its blocks (restored
bit-identical later; nothing refeeds) when the modeled D2H+H2D round
trip beats the modeled re-prefill, recompute-eviction otherwise.
Swapped streams are restored FIFO ahead of new admissions.

The scheduler also supports plain decode streams (the cloud-centric
baseline) through ``decode_iteration``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core import verifier as V
from repro.serving.engine import CloudEngine
from repro.serving.link import CloudLatencyModel, SimClock
from repro.serving.swap import PREEMPT_POLICIES, pick_victim
from repro.serving.trace import NULL_TRACER


@dataclass
class PrefillRequest:
    req_id: int
    tokens: np.ndarray            # (T,) prompt
    slot: int = -1
    arrival_ms: float = 0.0       # absolute arrival on the shared clock
    # leading prompt tokens mapped from the shared prefix index at
    # admission (share_prefix): already cached, so the batch feeds (and
    # the latency model charges) only tokens[shared:]
    shared: int = 0
    # optional per-stream latency budgets (serving/swap.StreamSLO),
    # consumed by the "slo-aware" preemption policy
    slo: object = None


@dataclass
class VerifyRequest:
    req_id: int
    slot: int
    uncached: np.ndarray          # device-accepted tokens not yet cloud-cached
    draft: np.ndarray             # (gamma,) pending-verify tokens
    q_sparse: list                # compressed SLM dists per draft position
    sampling: str = "greedy"
    start_pos: int = 0            # absolute position of uncached[0]
    arrival_ms: float = 0.0       # absolute arrival on the shared clock
    # full accepted stream (prompt + output).  When given, the request is
    # *restartable*: if its slot is preempted (paged pool dry), the
    # scheduler rewinds the request and re-derives ``uncached`` from the
    # new cache frontier (a from-scratch partial prefill) instead of
    # aborting the stream.  CloudClient always supplies it.
    seq: np.ndarray | None = None
    # internal
    fed: int = 0
    rows: list = field(default_factory=list)
    # rows entries: (abs_pos, fused (tok, p_draft, topk_idx, topk_val))
    # in fused mode, (abs_pos, full logits row) in legacy mode


@dataclass
class SchedulerEvent:
    kind: str                     # "prefill_done" | "verify_done"
    req_id: int
    slot: int
    result: object = None         # VerifyResult for verify_done
    last_logits: np.ndarray = None


class VerificationAwareScheduler:
    def __init__(self, engine: CloudEngine, *, chunk: int = 32,
                 latency: CloudLatencyModel | None = None,
                 rng: np.random.Generator | None = None,
                 clock: SimClock | None = None,
                 fused: bool = True,
                 preempt_policy: str | None = None,
                 tracer=None, replica: int = 0):
        self.engine = engine
        self.chunk = chunk
        self.fused = fused
        # tracing (serving/trace.py): every clock charge below becomes a
        # typed span tagged with the request ids / slot it served; the
        # NULL_TRACER default keeps the disabled path allocation-free
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.replica = replica
        if self.tracer.enabled:
            alloc = getattr(engine, "allocator", None)
            if alloc is not None:
                alloc.tracer = self.tracer
                alloc.trace_replica = replica
            swp = getattr(engine, "swap_manager", None)
            if swp is not None:
                swp.tracer = self.tracer
                swp.trace_replica = replica
        policy = (preempt_policy
                  or getattr(getattr(engine, "cfg", None),
                             "preempt_policy", None)
                  or "youngest")
        if policy not in PREEMPT_POLICIES:
            raise ValueError(f"unknown preempt_policy {policy!r}; "
                             f"have {PREEMPT_POLICIES}")
        self.preempt_policy = policy
        # host swap tier (engine-owned; None without --swap / kv_swap)
        self.swap = getattr(engine, "swap_manager", None)
        self.latency = latency or CloudLatencyModel()
        self.rng = rng or np.random.default_rng(0)
        self.clock = clock or SimClock()
        self.prefill_q: deque[PrefillRequest] = deque()
        self.verify_q: deque[VerifyRequest] = deque()
        self.active_verify: list[VerifyRequest] = []
        # FIFO: released slots go to the back so churn round-robins over
        # the physical batch rows instead of one slot absorbing it all
        self.free_slots: deque[int] = deque(range(engine.max_slots))
        self.cloud_len = np.zeros(engine.max_slots, np.int64)
        self.last_row: dict[int, np.ndarray] = {}  # slot -> last prefill row
        self.iterations = 0           # iterations that executed a batch
        self.prefill_iterations = 0
        self.verify_iterations = 0
        self.verify_occupancy: list[int] = []  # slots packed per verify iter
        self.verify_tokens_fed: list[int] = []  # tokens packed per verify iter
        self._req_counter = 0
        # paged-cache policy state: admission order (for youngest-first
        # preemption), per-slot prompt/SLO metadata (swap re-matching and
        # slo-aware victim selection) and preemption telemetry
        self.slot_age = np.full(engine.max_slots, -1, np.int64)
        self._admit_counter = 0
        self.slot_prompt: dict[int, np.ndarray] = {}
        self.slot_slo: dict[int, tuple] = {}   # slot -> (ttft_abs, ddl_abs)
        self._first_emit: set[int] = set()     # slots past their first emit
        self.recompute_evictions = 0
        self.swap_evictions = 0
        self.swap_expirations = 0   # swap-ins degraded: shared lead died
        self.preempted_refed_tokens = 0
        self.admission_swaps = 0    # proactive swap-outs at admission
        self.prefill_fed_tokens = 0  # cumulative prompt tokens actually fed
        # consecutive verify iterations that deferred EVERY chunk with
        # nothing evicted and nothing else executing — a growing streak
        # means no stream can ever free blocks (all holders
        # non-restartable), which must fail loudly, not spin the clock
        self._defer_streak = 0

    @property
    def sim_ms(self) -> float:
        return self.clock.now_ms

    @property
    def preemptions(self) -> int:
        """Total evictions, whatever the disposition."""
        return self.recompute_evictions + self.swap_evictions

    def slot_slack_ms(self, slot: int, now: float) -> float:
        """Remaining SLO budget of the stream on ``slot``: time to its
        TTFT bound (until the first verified emission) or completion
        deadline, whichever binds.  ``inf`` without an SLO — such
        streams are the preferred victims under ``slo-aware``."""
        slo = self.slot_slo.get(slot)
        if slo is None:
            return float("inf")
        ttft_abs, deadline_abs = slo
        lim = (deadline_abs if slot in self._first_emit
               else min(ttft_abs, deadline_abs))
        return lim - now

    def next_req_id(self) -> int:
        """Globally unique request id (unique per scheduler, so events
        from concurrent clients never collide)."""
        self._req_counter += 1
        return self._req_counter

    @property
    def mean_verify_occupancy(self) -> float:
        occ = self.verify_occupancy
        return float(np.mean(occ)) if occ else 0.0

    # ------------------------------------------------------------------
    def submit_prefill(self, req: PrefillRequest):
        self.prefill_q.append(req)

    def submit_verify(self, req: VerifyRequest):
        assert self.chunk >= len(req.draft) + 1, \
            "Sarathi chunk must cover a draft chunk (+1) so rejected-draft " \
            "cache entries are overwritten before any query can attend to them"
        if self.fused:
            rows_max = getattr(self.engine, "verify_rows_max", self.chunk)
            assert rows_max >= len(req.draft) + 1, \
                "engine.verify_rows_max must cover gamma+1 verification rows"
        req.start_pos = int(self.cloud_len[req.slot])
        self.verify_q.append(req)

    def release_slot(self, slot: int):
        if self.swap is not None:
            self.swap.drop(slot)       # session over: host payload gone
        self.slot_prompt.pop(slot, None)
        self.slot_slo.pop(slot, None)
        self._first_emit.discard(slot)
        self.engine.reset_slot(slot)
        if self.swap is not None:
            # exit-time demotion to the content-addressed host store is
            # a D2H peek: charge it to the modeled link
            nbytes = self.swap.take_uncharged()
            t0 = self.clock.now_ms
            self.clock.advance(self.latency.host_transfer_ms(nbytes))
            if self.tracer.enabled and nbytes:
                self.tracer.span(t0, self.clock.now_ms, "swap_demote",
                                 replica=self.replica, slot=slot,
                                 nbytes=nbytes)
        self.cloud_len[slot] = 0
        self.slot_age[slot] = -1
        self.free_slots.append(slot)   # FIFO: reuse round-robins over rows

    def has_work(self) -> bool:
        return bool(self.prefill_q or self.verify_q or self.active_verify)

    def cancel_requests(self, req_ids: set) -> None:
        """Drop every queued or in-flight request in ``req_ids`` (client
        cancellation / disconnect).  Request state is simply discarded —
        the caller is responsible for releasing the slot afterwards
        (``release_slot``), which frees blocks, drops swap state and
        decrefs shared prefixes.  Purging *before* the release matters:
        a freed slot row may be re-assigned to a new stream, and a stale
        request must never execute against the new owner's cache."""
        if not req_ids:
            return
        self.prefill_q = deque(r for r in self.prefill_q
                               if r.req_id not in req_ids)
        self.verify_q = deque(r for r in self.verify_q
                              if r.req_id not in req_ids)
        self.active_verify = [r for r in self.active_verify
                              if r.req_id not in req_ids]

    def export_requests(self, req_ids: set) -> list[VerifyRequest]:
        """Remove and return the verify requests in ``req_ids`` (replica
        death: the router re-places a dying replica's sessions on
        survivors).  Unlike :meth:`cancel_requests` the requests come
        back to the caller: each carries its full accepted stream in
        ``seq`` — the same restartability contract the recompute
        eviction path relies on — so the survivor can re-prefill the
        stream from scratch and re-run the parked verify on top.
        Queued prompt prefills in ``req_ids`` are simply dropped; the
        re-placement re-prefills the full stream anyway."""
        if not req_ids:
            return []
        out = [r for r in list(self.active_verify) + list(self.verify_q)
               if r.req_id in req_ids]
        self.cancel_requests(req_ids)
        return out

    # ------------------------------------------------------------------
    def run_iteration(self) -> list[SchedulerEvent]:
        """One scheduling iteration (one trip through Algorithm 1's loop).

        Returns completion events.  If no queued request has arrived yet
        (shared-clock semantics), fast-forwards the clock to the next
        arrival and returns [] — callers loop while ``has_work()``.
        """
        self._swap_in_ready()
        now = self.clock.now_ms
        if self.prefill_q and self.free_slots and \
                any(r.arrival_ms <= now for r in self.prefill_q):
            evs = self._prefill_iteration(now)
            if evs:
                self.iterations += 1
                return evs
        if self.verify_q or self.active_verify:
            evs = self._verify_iteration(now)
            if evs is not None:
                self.iterations += 1
                return evs
        # Nothing executable at `now`: fast-forward to the next *future*
        # arrival.  Requests that have already arrived but are blocked
        # (e.g. a prefill with no free slot) must not pin the clock —
        # unblocking them needs an external action (slot release), not
        # time.
        future = [a for a in
                  ([r.arrival_ms for r in self.prefill_q]
                   + [r.arrival_ms for r in self.verify_q])
                  if a > now]
        if future:
            t0 = self.clock.now_ms
            self.clock.advance_to(min(future))
            if self.tracer.enabled and self.clock.now_ms > t0:
                self.tracer.span(t0, self.clock.now_ms, "idle",
                                 replica=self.replica)
        return []

    # -- prefill (lines 5-11) ------------------------------------------
    def _swap_in_reserve(self) -> int:
        """Blocks fresh admissions must leave untouched for the
        FIFO-head swapped stream: without this, a continuous arrival
        stream could consume every freed block the moment it appears
        and starve a large swapped stream's return indefinitely."""
        if self.swap is None:
            return 0
        slots = self.swap.swapped_slots
        return self.swap.blocks_needed(slots[0]) if slots else 0

    def _prefill_iteration(self, now: float) -> list[SchedulerEvent]:
        alloc = getattr(self.engine, "allocator", None)
        blocks_exhausted = False
        batch: list[PrefillRequest] = []
        rest: deque[PrefillRequest] = deque()
        while self.prefill_q:
            req = self.prefill_q.popleft()
            # admission is memory-bound on a paged engine: a free batch
            # row AND enough free blocks for the prompt — minus any
            # leading blocks the prefix index already holds (a shared
            # system prompt costs its blocks once, not once per stream).
            # On dense the slot row is the only resource.  Once one
            # arrived request is deferred for blocks, later
            # (block-needing) requests are too — FCFS, so a steady
            # stream of small prompts cannot starve a large one
            if req.arrival_ms > now or not self.free_slots:
                rest.append(req)    # cheap defers skip the prefix probe
                continue
            if blocks_exhausted:
                # FCFS tail: a paged prompt always needs >= 1 fresh
                # block (matching caps at len-1 tokens), so nothing
                # behind the first block-deferred request can admit —
                # skip its probe too
                rest.append(req)
                continue
            need = 0
            matched: list = []
            if alloc is not None:
                full_need = alloc.blocks_for(len(req.tokens))
                if full_need > alloc.n_blocks:
                    # can never be satisfied, not even by draining the
                    # pool (shared blocks may vanish with their owners):
                    # fail with the sizing contract instead of stalling
                    raise RuntimeError(
                        f"paged KV pool too small for prompt of "
                        f"{len(req.tokens)} tokens: needs {full_need} "
                        f"blocks, pool has {alloc.n_blocks} total "
                        f"(block_size={alloc.block_size}) — grow "
                        f"pool_blocks")
                matched = alloc.match_prefix(req.tokens)
                need = full_need - len(matched)
                # supply counts cached-free (reclaimable) blocks, minus
                # the matched ones this prompt is about to revive
                avail = (alloc.allocatable_blocks(matched)
                         - self._swap_in_reserve())
                if need > avail and self._admission_swap(need - avail):
                    # swap-aware admission: an idle cold stream made room
                    avail = (alloc.allocatable_blocks(matched)
                             - self._swap_in_reserve())
                if need > avail:
                    blocks_exhausted = True
                    rest.append(req)
                    continue
            req.slot = self.free_slots.popleft()
            self._admit_counter += 1
            self.slot_age[req.slot] = self._admit_counter
            # prompt retained for the swap tier (shared-lead re-matching),
            # SLO budgets anchored at arrival for slo-aware preemption
            self.slot_prompt[req.slot] = np.asarray(req.tokens)
            if req.slo is not None:
                self.slot_slo[req.slot] = (
                    req.arrival_ms + req.slo.ttft_ms,
                    req.arrival_ms + req.slo.deadline_ms)
            if alloc is not None:
                # allocate (and prefix-share) eagerly so the request
                # admitted next in this same loop sees the live free
                # count AND can adopt this prompt's just-registered
                # blocks; the probe above is still valid (nothing is
                # released between probe and admission)
                req.shared = self.engine.alloc_prompt(req.slot, req.tokens,
                                                      bids=matched)
            batch.append(req)
        self.prefill_q = rest
        if not batch:
            return []  # wait for a free slot
        self._defer_streak = 0         # admission is forward progress

        B = self.engine.max_slots
        C = max(len(r.tokens) for r in batch)
        tokens = np.zeros((B, C), np.int32)
        positions = np.full((B, C), -1, np.int32)
        for r in batch:
            T, m = len(r.tokens), r.shared
            # columns align with absolute positions; a shared prefix is
            # leading padding.  This is what keeps same-batch adoption
            # safe when the bucket ladder splits a wide prompt batch
            # into sequential sub-chunks: sub-chunk k scatters position
            # range k for EVERY slot before any later sub-chunk's rows
            # attend over it, so an adopter's suffix never reads prefix
            # positions its filler has not yet written
            tokens[r.slot, m:T] = r.tokens[m:]
            positions[r.slot, m:T] = np.arange(m, T)
        # one full-vocab row per slot crosses to the host here (the
        # sampling verifier's pre-draft row); verify iterations never
        # transfer a vocab-sized tensor
        t_exec0 = self.clock.now_ms
        b0 = getattr(self.engine, "bytes_to_host", 0)
        last_rows = self.engine.prefill(tokens, positions)
        moved = getattr(self.engine, "bytes_to_host", 0) - b0

        events = []
        # shared prefix tokens are cache hits: neither fed nor charged;
        # blocks adopted from the content-addressed host store are
        # charged as H2D transfers instead (take_uncharged)
        total = sum(len(r.tokens) - r.shared for r in batch)
        self.prefill_fed_tokens += total
        adopted = self.swap.take_uncharged() if self.swap is not None else 0
        self.clock.advance(self.latency.prefill_ms(total)
                           + self.latency.host_transfer_ms(moved + adopted))
        self.prefill_iterations += 1
        if self.tracer.enabled:
            self.tracer.span(t_exec0, self.clock.now_ms, "prefill",
                             replica=self.replica,
                             rids=tuple(r.req_id for r in batch),
                             tokens=total, nbytes=moved + adopted)
        for r in batch:
            T = len(r.tokens)
            self.cloud_len[r.slot] = T
            self.last_row[r.slot] = last_rows[r.slot]
            events.append(SchedulerEvent(
                "prefill_done", r.req_id, r.slot,
                last_logits=last_rows[r.slot]))
        return events

    # -- verification partial prefill (lines 12-21) ---------------------
    def _verify_iteration(self, now: float) -> list[SchedulerEvent] | None:
        """Returns events for the executed batch, or None if no verify
        chunk was admissible at ``now`` (caller decides how to wait)."""
        still: deque[VerifyRequest] = deque()
        while self.verify_q:
            r = self.verify_q.popleft()
            if r.arrival_ms <= now:
                self.active_verify.append(r)
            else:
                still.append(r)
        self.verify_q = still

        B = self.engine.max_slots
        C = self.chunk
        R = getattr(self.engine, "verify_rows_max", C) if self.fused else 0
        tokens = np.zeros((B, C), np.int32)
        positions = np.full((B, C), -1, np.int32)
        targets = np.full((B, C), -1, np.int32)
        sel_idx = np.full((B, max(R, 1)), -1, np.int32)
        kept: dict[int, list[int]] = {}  # slot -> kept local row indices
        feeding: list[tuple[VerifyRequest, int, int]] = []
        used_slots = set()
        for req in self.active_verify:
            if req.slot in used_slots:
                continue  # one chunk per slot per iteration
            if self._slot_swapped(req.slot):
                continue  # cache on the host: waits for swap-in
            seq = np.concatenate([req.uncached, req.draft]).astype(np.int32)
            n = min(C, len(seq) - req.fed)
            if n <= 0:
                continue
            tokens[req.slot, :n] = seq[req.fed:req.fed + n]
            positions[req.slot, :n] = (req.start_pos + req.fed
                                       + np.arange(n))
            if self.fused:
                # row i predicts seq[fed+i+1]: the verifier's accept test
                # needs its probability, gathered on device.  The last
                # row of the request (the bonus row) has no target.
                nt = min(n, len(seq) - req.fed - 1)
                targets[req.slot, :nt] = seq[req.fed + 1:req.fed + 1 + nt]
                # rows the verifier will consume: the last gamma+1 of the
                # request — the device computes p/top-k only for these
                keep_from = len(seq) - len(req.draft) - 1
                local = [i for i in range(n) if req.fed + i >= keep_from]
                kept[req.slot] = local
                sel_idx[req.slot, :len(local)] = local
            feeding.append((req, req.fed, n))
            used_slots.add(req.slot)

        if not feeding:
            return None
        if not self._reserve_blocks(feeding, tokens, positions, targets,
                                    sel_idx, kept):
            # every admissible chunk was preempted away: charge the
            # scheduling work so the shared clock (and the server's
            # stall detector) sees progress, and retry next iteration
            t0 = self.clock.now_ms
            self.clock.advance(self.latency.ms_scheduler)
            if self.tracer.enabled:
                self.tracer.span(t0, self.clock.now_ms, "sched",
                                 replica=self.replica)
            return None
        t_exec0 = self.clock.now_ms
        b0 = getattr(self.engine, "bytes_to_host", 0)
        if self.fused:
            need_dists = any(r.sampling != "greedy" for r, _, _ in feeding)
            rows = self.engine.feed(tokens, positions, targets, sel_idx,
                                    need_dists=need_dists)
        else:
            logits = self.engine.feed_logits(tokens, positions)
        moved = getattr(self.engine, "bytes_to_host", 0) - b0
        total = sum(n for _, _, n in feeding)
        self.clock.advance(self.latency.iteration_ms(total)
                           + self.latency.host_transfer_ms(moved))
        self.verify_iterations += 1
        self.verify_occupancy.append(len(feeding))
        self.verify_tokens_fed.append(total)
        if self.tracer.enabled:
            self.tracer.span(t_exec0, self.clock.now_ms, "verify",
                             replica=self.replica,
                             rids=tuple(r.req_id for r, _, _ in feeding),
                             tokens=total, nbytes=moved)

        events = []
        for req, fed0, n in feeding:
            gamma = len(req.draft)
            seq_len = len(req.uncached) + gamma
            if self.fused:
                for r, i in enumerate(kept[req.slot]):
                    req.rows.append((req.start_pos + fed0 + i, (
                        int(rows.token_id[req.slot, r]),
                        float(rows.p_draft[req.slot, r]),
                        rows.topk_idx[req.slot, r],
                        rows.topk_val[req.slot, r])))
            else:
                keep_from = seq_len - gamma - 1
                for i in range(n):
                    idx = fed0 + i
                    if idx >= keep_from:
                        req.rows.append((req.start_pos + idx,
                                         logits[req.slot, i]))
            req.fed = fed0 + n
            self.cloud_len[req.slot] = req.start_pos + req.fed
            if req.fed >= seq_len:
                events.append(self._finish_verify(req))
        self.active_verify = [r for r in self.active_verify
                              if r.fed < len(r.uncached) + len(r.draft)]
        return events

    # -- paged-pool admission + preemption ------------------------------
    def _reserve_blocks(self, feeding, tokens, positions, targets,
                        sel_idx, kept) -> bool:
        """Memory admission for one verify iteration on a paged engine.

        Ensures the block pool can supply every feeding slot's growth;
        when it cannot, the *youngest* block-holding stream is preempted
        (recompute-style eviction: its blocks return to the pool, its
        cloud frontier rewinds to zero, and its pending requests restart
        as from-scratch partial prefills — re-derived from ``req.seq``
        the next time they are fed).  The oldest block holder is never
        evicted, which guarantees forward progress.  Returns False when
        the eviction emptied the feeding set (retry next iteration);
        no-op (True) on dense engines.
        """
        alloc = getattr(self.engine, "allocator", None)
        if alloc is None:
            return True

        def demand(entry):
            req, fed0, n = entry
            lo = req.start_pos + fed0
            upto = min(lo + n, self.engine.s_max)
            # growth blocks plus copy-on-write forks: a chunk that wraps
            # into (or otherwise writes) a block still shared with a
            # sibling must clone it before writing
            return (alloc.needed(req.slot, upto)
                    + alloc.cow_demand(req.slot, lo, lo + n))

        evicted = False
        while feeding:
            if sum(demand(e) for e in feeding) <= alloc.allocatable_blocks():
                self._defer_streak = 0
                return True
            victim = self._pick_victim()
            if victim is not None and self._evict(victim, feeding, tokens,
                                                  positions, targets,
                                                  sel_idx, kept):
                evicted = True
                continue
            # No evictable stream (the only holder is protected or not
            # restartable): defer the youngest feeding chunk that
            # actually needs blocks — zero-demand chunks write into
            # their last partial block and can always proceed (and
            # finishing them is what releases blocks).  The deferred
            # request stays queued untouched.
            needy = [e for e in feeding if demand(e) > 0]
            entry = max(needy, key=lambda e: self.slot_age[e[0].slot])
            req = entry[0]
            own = int(alloc.n_blocks_of[req.slot])
            if len(feeding) == 1 and alloc.used_blocks == own:
                raise RuntimeError(
                    f"paged KV pool too small for a single stream: chunk "
                    f"needs {demand(entry)} blocks beyond the {own} it "
                    f"holds, pool has {alloc.free_blocks}/"
                    f"{alloc.n_blocks} free (block_size="
                    f"{alloc.block_size}) — grow pool_blocks")
            self._withdraw(entry, feeding, tokens, positions, targets,
                           sel_idx, kept)
        # the whole batch was deferred: legitimate while other work
        # (or an eviction) can still free blocks, but an unbroken
        # streak of all-deferred iterations means nothing ever will —
        # every reserve success, eviction, or executed batch resets it
        self._defer_streak = 0 if evicted else self._defer_streak + 1
        if self._defer_streak > 4 * self.engine.max_slots + 16:
            raise RuntimeError(
                f"paged KV pool deadlocked: every verify chunk deferred "
                f"for {self._defer_streak} consecutive iterations with "
                f"no evictable stream ({alloc.free_blocks}/"
                f"{alloc.n_blocks} blocks free, block_size="
                f"{alloc.block_size}).  Streams submitted without "
                f"VerifyRequest.seq cannot be preempted — grow "
                f"pool_blocks or supply seq")
        return False

    @staticmethod
    def _withdraw(entry, feeding, tokens, positions, targets, sel_idx,
                  kept) -> None:
        """Pull one chunk out of the current batch without touching its
        request state — it simply waits for a later iteration."""
        slot = entry[0].slot
        tokens[slot, :] = 0
        positions[slot, :] = -1
        targets[slot, :] = -1
        sel_idx[slot, :] = -1
        kept.pop(slot, None)
        feeding.remove(entry)

    def _slot_restartable(self, slot: int) -> bool:
        """A slot can be preempted only if every pending request for it
        carries the full accepted stream (``seq``) so the scheduler can
        re-derive the partial prefill from a cold cache."""
        return all(r.seq is not None
                   for r in list(self.active_verify) + list(self.verify_q)
                   if r.slot == slot)

    def _slot_swapped(self, slot: int) -> bool:
        """Whether ``slot``'s KV lives in the host store right now (the
        manager's stream table is the single source of truth)."""
        return self.swap is not None and self.swap.holds(slot)

    def _swap_possible(self, slot: int) -> bool:
        return self.swap is not None and self.swap.plan(slot) is not None

    def _pick_victim(self) -> int | None:
        """Block-holding victim by the configured policy (never the
        oldest holder — forward progress).  A candidate must either be
        restartable (recompute-eviction re-derives its partial prefill
        from ``VerifyRequest.seq``) or swappable (the host tier keeps
        its state, no restart needed)."""
        alloc = self.engine.allocator
        holders = [s for s in range(self.engine.max_slots)
                   if alloc.n_blocks_of[s] > 0]
        if len(holders) <= 1:
            return None
        oldest = min(holders, key=lambda s: self.slot_age[s])
        cands = [s for s in holders
                 if s != oldest and (self._slot_restartable(s)
                                     or self._swap_possible(s))]
        if not cands:
            return None
        return pick_victim(self.preempt_policy, cands, self)

    def _admission_swap(self, deficit: int) -> bool:
        """Swap-aware admission: make room for a queued prompt by swapping
        *idle* block holders (no pending verify work) to the host tier,
        rather than turning the prompt away.  Only cold streams are
        candidates — anything with an in-flight or queued request keeps
        its device residency.  Returns True if any blocks were freed."""
        if self.swap is None:
            return False
        alloc = self.engine.allocator
        busy = {r.slot for r in list(self.active_verify) + list(self.verify_q)}
        freed_any = False
        while deficit > 0:
            holders = [s for s in range(self.engine.max_slots)
                       if alloc.n_blocks_of[s] > 0]
            if len(holders) <= 1:
                break
            oldest = min(holders, key=lambda s: self.slot_age[s])
            cands = [s for s in holders
                     if s != oldest and s not in busy
                     and not self._slot_swapped(s)
                     and self._swap_possible(s)]
            if not cands:
                break
            victim = pick_victim(self.preempt_policy, cands, self)
            before = alloc.allocatable_blocks()
            t0 = self.clock.now_ms
            moved = self.swap.swap_out(victim, self.slot_prompt.get(victim),
                                       int(self.cloud_len[victim]))
            if moved is None:
                break
            self.swap_evictions += 1
            self.admission_swaps += 1
            self.clock.advance(self.latency.host_transfer_ms(moved))
            if self.tracer.enabled:
                self.tracer.span(t0, self.clock.now_ms, "swap_out",
                                 replica=self.replica, slot=victim,
                                 nbytes=moved)
                self.tracer.instant("admission_swap", replica=self.replica,
                                    slot=victim)
            deficit -= alloc.allocatable_blocks() - before
            freed_any = True
        return freed_any

    def _evict(self, slot: int, feeding, tokens, positions, targets,
               sel_idx, kept) -> bool:
        """Evict ``slot`` by the cheaper disposition: swap to the host
        tier when the modeled D2H+H2D round trip on its measured block
        bytes undercuts the modeled re-prefill of its accepted frontier
        (or when the stream cannot restart at all), recompute-eviction
        otherwise.  Returns True when blocks actually came back."""
        if self.swap is not None:
            p = self.swap.plan(slot)
            if p is not None:
                nbytes = p[2]
                frontier = int(self.cloud_len[slot])
                swap_ms = self.latency.swap_roundtrip_ms(nbytes)
                redo = frontier
                if alloc := getattr(self.engine, "allocator", None):
                    if alloc.retain_prefix:
                        # under retention a recompute restart re-matches
                        # its leading blocks (they park cached-free, not
                        # freed): the disposition compares against the
                        # cheaper, real refeed
                        prompt = self.slot_prompt.get(slot)
                        if prompt is not None:
                            redo -= (len(alloc.match_prefix(prompt))
                                     * alloc.block_size)
                redo_ms = self.latency.refeed_ms(max(0, redo), self.chunk)
                if swap_ms < redo_ms or not self._slot_restartable(slot):
                    t0 = self.clock.now_ms
                    moved = self.swap.swap_out(
                        slot, self.slot_prompt.get(slot), frontier)
                    if moved is not None:
                        self.swap_evictions += 1
                        self.clock.advance(
                            self.latency.host_transfer_ms(moved))
                        if self.tracer.enabled:
                            self.tracer.span(
                                t0, self.clock.now_ms, "swap_out",
                                replica=self.replica, slot=slot,
                                nbytes=moved)
                        for entry in feeding:
                            if entry[0].slot == slot:
                                self._withdraw(entry, feeding, tokens,
                                               positions, targets, sel_idx,
                                               kept)
                                break
                        return True
        if not self._slot_restartable(slot):
            return False               # cannot swap, cannot restart: defer
        self._preempt_slot(slot, feeding, tokens, positions, targets,
                           sel_idx, kept)
        return True

    def _swap_in_ready(self) -> None:
        """Restore swapped-out streams (FIFO over swap-out order) while
        the pool can take them — before admission, so returning streams
        are not starved by fresh prompts.  A stream whose shared lead
        expired from the prefix index while it was on the host (its
        sibling died) degrades to recompute-eviction: the host payload
        alone cannot rebuild the missing prefix KV."""
        if self.swap is None:
            return
        alloc = self.engine.allocator
        for slot in self.swap.swapped_slots:
            if self.swap.blocks_needed(slot) > alloc.allocatable_blocks():
                break                  # FIFO: no bypass (anti-starvation)
            res = self.swap.swap_in(slot)
            if res is None:
                self.swap_expirations += 1
                if self.tracer.enabled:
                    self.tracer.instant("swap_expire",
                                        replica=self.replica, slot=slot)
                self._rewind_slot(slot)
                continue
            frontier, nbytes = res
            self.cloud_len[slot] = frontier
            t0 = self.clock.now_ms
            self.clock.advance(self.latency.host_transfer_ms(nbytes))
            if self.tracer.enabled:
                self.tracer.span(t0, self.clock.now_ms, "swap_in",
                                 replica=self.replica, slot=slot,
                                 nbytes=nbytes)

    def _rewind_slot(self, slot: int) -> None:
        """Recompute-eviction bookkeeping: cloud frontier rewinds and
        pending requests refeed (re-derived from ``req.seq`` — the
        from-scratch partial prefill).  With prefix retention (or a live
        sibling) the restart first re-adopts whatever leading blocks the
        index still holds, so the refeed starts at the first unmatched
        token instead of zero."""
        self.last_row.pop(slot, None)
        reqs = [r for r in list(self.active_verify) + list(self.verify_q)
                if r.slot == slot]
        if self.tracer.enabled:
            # the rewind instant marks every serving span of these
            # requests before this point as wasted work ("preempted"
            # bucket in the stall attribution)
            self.tracer.instant("rewind", replica=self.replica, slot=slot,
                                rids=tuple(r.req_id for r in reqs))
        shared = 0
        if reqs and reqs[0].seq is not None:
            # the earliest request's seq is a prefix of every later one;
            # matching caps at len-1 so at least one token always feeds
            shared = self.engine.readopt_prefix(
                slot, np.asarray(reqs[0].seq)) \
                if hasattr(self.engine, "readopt_prefix") else 0
        self.cloud_len[slot] = shared
        for r in reqs:
            self.preempted_refed_tokens += max(
                0, r.start_pos + r.fed - shared)
            r.fed = 0
            r.rows = []
            r.start_pos = shared
            r.uncached = np.asarray(r.seq, np.int64)[shared:]

    def _preempt_slot(self, slot: int, feeding, tokens, positions,
                      targets, sel_idx, kept) -> None:
        """Recompute-evict ``slot``: blocks back to the pool, cloud
        frontier to 0, pending requests rewound to refeed from scratch;
        if the slot was in the current batch, its chunk is withdrawn."""
        if self.tracer.enabled:
            self.tracer.instant("preempt", replica=self.replica, slot=slot)
        self.engine.reset_slot(slot)            # frees + invalidates blocks
        self._rewind_slot(slot)
        for entry in feeding:
            if entry[0].slot == slot:
                self._withdraw(entry, feeding, tokens, positions, targets,
                               sel_idx, kept)
                break
        self.recompute_evictions += 1

    def _finish_verify(self, req: VerifyRequest) -> SchedulerEvent:
        gamma = len(req.draft)
        # rows for positions draft_start-1 .. draft_start+gamma-1
        need = gamma + 1
        rows = sorted(req.rows, key=lambda x: x[0])[-need:]
        if len(rows) < need:
            # Only a 1-row shortfall is legitimate: the first
            # verification right after prefill feeds no uncached token,
            # so the row preceding the draft is the prefill's last row
            # (retained per slot).  Anything else is a bookkeeping bug —
            # fail loudly instead of silently mis-aligning rows.
            if len(rows) != need - 1:
                raise RuntimeError(
                    f"verify req {req.req_id} (slot {req.slot}) retained "
                    f"{len(rows)} rows but needs {need}: drafts must be "
                    f"fed in full before verification")
            if req.slot not in self.last_row:
                raise RuntimeError(
                    f"verify req {req.req_id} needs the prefill row for "
                    f"slot {req.slot}, but no prefill was recorded")
            pre = self.last_row[req.slot]
            if self.fused:
                # the prefill row's target (draft[0]) is only known now;
                # mirror the device epilogue on the retained full row
                pre = V.fused_row_from_logits(pre, int(req.draft[0]),
                                              self.engine.verify_top_k)
            rows = [(-1, pre)] + rows
        if self.fused:
            ids = np.array([r[1][0] for r in rows])
            if req.sampling == "greedy":
                res = V.verify_greedy_ids(req.draft, ids)
            else:
                p_draft = np.array([rows[t][1][1] for t in range(gamma)])
                topk = [(rows[t][1][2], rows[t][1][3])
                        for t in range(need)]
                res = V.verify_sample_fused(req.draft, p_draft, topk,
                                            req.q_sparse, self.rng,
                                            self.engine.vocab)
        else:
            p_logits = np.stack([r[1] for r in rows])  # (gamma+1, V)
            if req.sampling == "greedy":
                res = V.verify_greedy(req.draft, p_logits)
            else:
                res = V.verify_sample(req.draft, p_logits, req.q_sparse,
                                      self.rng)
        # roll the cloud cache frontier back to the accepted prefix: the
        # rejected draft tokens were written to cache but their positions
        # will be overwritten by the corrected continuation (cache_write
        # is idempotent per position).
        accepted_abs = (req.start_pos + len(req.uncached) + res.n_accepted)
        self.cloud_len[req.slot] = accepted_abs
        self._first_emit.add(req.slot)   # TTFT budget met: deadline governs
        return SchedulerEvent("verify_done", req.req_id, req.slot, result=res)

    # -- plain decode (cloud-centric baseline) ---------------------------
    def decode_iteration(self, tokens: np.ndarray, positions: np.ndarray):
        """tokens/positions: (max_slots, 1); position -1 = idle slot.
        Returns the engine's fused DecodeRows (argmax + top-k support)."""
        t0 = self.clock.now_ms
        b0 = getattr(self.engine, "bytes_to_host", 0)
        rows = self.engine.decode(tokens, positions)
        moved = getattr(self.engine, "bytes_to_host", 0) - b0
        active = int((positions >= 0).sum())
        self.clock.advance(self.latency.iteration_ms(active)
                           + self.latency.host_transfer_ms(moved))
        if self.tracer.enabled:
            self.tracer.span(t0, self.clock.now_ms, "decode",
                             replica=self.replica, tokens=active,
                             nbytes=moved)
        return rows
