"""Multi-replica cloud fleet: ``ReplicaRouter`` (ROADMAP direction 2).

Synera's offloading decision is point-to-point in the base system — one
device talks to one cloud engine.  At fleet scale the offload *target*
is itself a choice: each replica is an independent ``CloudEngine`` +
scheduler with its own block pool, prefix index and swap tier, so where
a request lands determines whether its system prompt is a cache hit or
a full refeed.  The router fronts N ``SyneraServer`` replicas (built by
``server.build_fleet`` on one shared clock and one ``DeviceRuntime``)
and places each incoming session by a pluggable policy:

``round-robin``
    Rotate over alive replicas.  The identity oracle: placement is
    oblivious to all state, so any output divergence under it is a
    correctness bug, not a routing artifact.

``least-loaded``
    Fewest live sessions, then most allocatable blocks, then fewest
    sessions ever served (so an idle fleet still spreads), then index.

``prefix-affinity``
    Probe each replica's chain-hash prefix index — device blocks via
    ``BlockAllocator.match_prefix``, then the content-addressed host
    tier via ``HostSwapManager.host_match_chain`` — and route to the
    replica already holding the longest prefix of the prompt; ties and
    cold prompts fall back to least-loaded.  This is how routing and
    the persistent prefix cache compose: a recurring system prompt
    concentrates on the replica that already has it.

Two degradation paths keep the fleet serving under stress:

* **Saturation**: when every alive replica is past its queue cap, the
  router degrades the stream to *device-only* generation — the SLM
  finishes solo (``generate_steps(use_cloud=False)`` never yields a
  cloud call, so the session completes synchronously at open) — instead
  of 429ing.  This is the Synera offloading decision generalized to a
  fleet: "nowhere worth offloading to" is just another reason not to
  offload.

* **Replica death**: ``kill_replica`` marks a replica dead (poisoning
  its engine), exports every live session and re-places each on a
  survivor as a from-scratch prefill of its accepted stream with the
  parked verify re-run on top — the recompute-eviction restart contract
  (``VerifyRequest.seq``).  Nothing on the dead replica is released:
  its pool dies with it.

Token identity is the invariant throughout: greedy token streams are
deterministic functions of tokens and positions only, and none of
placement, packing, re-placement or degradation changes either — every
stream is byte-identical to the single-engine run (tests/test_router).
"""
from __future__ import annotations

import numpy as np

from repro.serving.server import (DONE, DeviceSession, ServerStats,
                                  SyneraServer, aggregate_server_stats)
from repro.serving.trace import hist_add

ROUTE_POLICIES = ("round-robin", "least-loaded", "prefix-affinity")


class ReplicaRouter:
    """Places device sessions across N ``SyneraServer`` replicas."""

    def __init__(self, replicas: list[SyneraServer], *,
                 policy: str = "least-loaded",
                 replica_queue_cap: int = 0):
        if policy not in ROUTE_POLICIES:
            raise ValueError(f"unknown route policy {policy!r}; "
                             f"expected one of {ROUTE_POLICIES}")
        if not replicas:
            raise ValueError("need at least one replica")
        if len({id(s.clock) for s in replicas}) != 1:
            raise ValueError("replicas must share one clock "
                             "(use server.build_fleet)")
        if len({id(s.device) for s in replicas}) != 1:
            raise ValueError("replicas must share one DeviceRuntime")
        self.replicas = list(replicas)
        self.device = replicas[0].device
        self.clock = replicas[0].clock
        # one tracer serves the fleet (build_fleet hands the same
        # instance to every replica); router-level events — degrades,
        # reroutes, replica kills — stamp through it
        self.tracer = replicas[0].tracer
        self.policy = policy
        # live sessions a replica may hold before it counts as saturated
        # (0 = unbounded; saturation of ALL replicas => degrade-to-device)
        self.replica_queue_cap = replica_queue_cap
        self.dead = [False] * len(replicas)
        self.sessions: list[DeviceSession] = []   # fleet-wide, open order
        self.owner: dict[int, int] = {}           # id(session) -> replica (-1 = degraded)
        self._rr = 0
        # fleet telemetry (ServerStats fleet fields)
        self.degraded_streams = 0
        self.rerouted_sessions = 0
        self.affinity_hits = 0
        # gateway front-door attributes (same duck type as SyneraServer)
        self.ext_queue_depth = 0
        self.rejected_requests = 0

    # -- placement ------------------------------------------------------
    def _alive(self) -> list[int]:
        return [i for i in range(len(self.replicas)) if not self.dead[i]]

    def _live_load(self, i: int) -> int:
        srv = self.replicas[i]
        return len(srv.sessions) - srv._done_count

    def _has_capacity(self, i: int) -> bool:
        cap = self.replica_queue_cap
        return cap <= 0 or self._live_load(i) < cap

    def _allocatable(self, i: int) -> int:
        a = getattr(self.replicas[i].engine, "allocator", None)
        return a.allocatable_blocks() if a is not None else 0

    def _least_loaded(self, cands: list[int]) -> int:
        # most allocatable blocks breaks live-load ties; fewest sessions
        # ever served breaks full ties so an idle fleet still spreads
        return min(cands, key=lambda i: (self._live_load(i),
                                         -self._allocatable(i),
                                         len(self.replicas[i].sessions), i))

    def _affinity_tokens(self, i: int, prompt) -> int:
        """Tokens of ``prompt`` replica ``i`` already holds: leading full
        blocks in its device prefix index, then the chain continued in
        its content-addressed host store."""
        eng = self.replicas[i].engine
        alloc = getattr(eng, "allocator", None)
        if alloc is None or not alloc.share_prefix:
            return 0
        toks = np.asarray([int(t) for t in prompt], np.int64)
        n_blocks = len(alloc.match_prefix(toks))
        swap = getattr(eng, "swap_manager", None)
        if swap is not None and getattr(swap, "content_addressed", False):
            n_blocks += len(swap.host_match_chain(toks, n_blocks))
        return n_blocks * alloc.block_size

    def place(self, prompt) -> int | None:
        """Pick a replica index for a new session; None when every alive
        replica is saturated past its queue cap (degrade-to-device)."""
        cands = [i for i in self._alive() if self._has_capacity(i)]
        if not cands:
            return None
        if self.policy == "round-robin":
            n = len(self.replicas)
            for k in range(n):
                i = (self._rr + k) % n
                if i in cands:
                    self._rr = i + 1
                    return i
            return None                      # unreachable: cands nonempty
        if self.policy == "prefix-affinity":
            scored = [(self._affinity_tokens(i, prompt), i) for i in cands]
            best = max(m for m, _ in scored)
            if best > 0:
                self.affinity_hits += 1
                return self._least_loaded([i for m, i in scored if m == best])
        return self._least_loaded(cands)

    # -- session lifecycle ---------------------------------------------
    def open_session(self, prompt, max_new: int, *,
                     arrival_ms: float | None = None,
                     profile_mode: bool = False,
                     slo: object = None,
                     emit=None) -> DeviceSession:
        """Route and open one device stream (SyneraServer.open_session
        signature).  A saturated fleet degrades the stream to
        device-only generation — it completes before this returns."""
        ridx = self.place(prompt)
        if ridx is None:
            s = self._degrade(prompt, max_new, arrival_ms=arrival_ms,
                              profile_mode=profile_mode, emit=emit)
        else:
            s = self.replicas[ridx].open_session(
                prompt, max_new, arrival_ms=arrival_ms,
                profile_mode=profile_mode, slo=slo, emit=emit)
            self.owner[id(s)] = ridx
        self.sessions.append(s)
        return s

    def _degrade(self, prompt, max_new: int, *,
                 arrival_ms: float | None = None,
                 profile_mode: bool = False, emit=None) -> DeviceSession:
        """Device-only completion: with ``use_cloud=False`` the
        generation coroutine never yields a cloud call, so one resume
        drives it to StopIteration — the stream finishes solo on the
        SLM, off the shared clock's critical path."""
        self.degraded_streams += 1
        start = self.clock.now_ms if arrival_ms is None else arrival_ms
        s = DeviceSession(sid=-1, gen=None, client=None, start_ms=start)
        if self.tracer.enabled:
            self.tracer.instant("degrade", start)
            s.trace_uid = self.tracer.stream_begin(
                "stream", start,
                meta={"degraded": True, "prompt_tokens": len(prompt),
                      "max_new": max_new})

        def _emit(tokens, t_ms, _s=s, _user=emit):
            if _s.ttft_ms is None:
                _s.ttft_ms = t_ms
            _s.n_emitted += len(tokens)
            if _user is not None:
                _user(tokens, t_ms)

        gen = self.device.generate_steps(prompt, max_new, use_cloud=False,
                                         profile_mode=profile_mode,
                                         emit=_emit)
        s.gen = gen
        try:
            call = gen.send(None)
            raise RuntimeError(
                f"device-only generation yielded a cloud call ({call.kind})")
        except StopIteration as e:
            s.metrics = e.value
            s.e2e_ms = e.value.timeline.t_ms
            s.state = DONE
            if self.tracer.enabled and s.trace_uid >= 0:
                tl = e.value.timeline
                self.tracer.stream_end(
                    s.trace_uid, start + tl.t_ms,
                    meta={"wall_ms": tl.t_ms,
                          "tokens": len(e.value.tokens),
                          "buckets": tl.buckets()})
        self.owner[id(s)] = -1
        return s

    def cancel(self, session: DeviceSession) -> bool:
        """Tear down a mid-flight stream on whichever replica owns it.
        Degraded sessions completed at open, so there is nothing to
        cancel (returns False, like any done session)."""
        ridx = self.owner.get(id(session))
        if ridx is None or ridx < 0:
            return False
        return self.replicas[ridx].cancel(session)

    # -- fault injection ------------------------------------------------
    def kill_replica(self, idx: int) -> int:
        """Mark replica ``idx`` dead and re-place its live sessions on
        survivors.  Returns the number of sessions moved.

        The dead engine is poisoned first (``mark_dead``) so any stray
        dispatch fails loudly; each live session is then exported —
        its parked verify carries the full accepted stream — and
        imported on a survivor chosen by the routing policy (probing
        with the accepted stream under prefix-affinity; queue caps are
        ignored, survivors must absorb the failover).  Completed
        sessions keep their metrics and stay where they are."""
        if self.dead[idx]:
            return 0
        self.dead[idx] = True
        srv = self.replicas[idx]
        if hasattr(srv.engine, "mark_dead"):
            srv.engine.mark_dead()
        if self.tracer.enabled:
            self.tracer.instant("replica_kill", replica=idx)
        moved = 0
        for s in [x for x in srv.sessions if not x.done]:
            pending = srv.export_session(s)
            probe = pending.seq if pending is not None else None
            target = self._place_failover(probe)
            self.replicas[target].import_session(s, pending)
            self.owner[id(s)] = target
            if self.tracer.enabled and s.trace_uid >= 0:
                self.tracer.stream_instant(s.trace_uid, "reroute",
                                           self.clock.now_ms, n=target)
            moved += 1
        self.rerouted_sessions += moved
        return moved

    def _place_failover(self, probe) -> int:
        alive = self._alive()
        if not alive:
            raise RuntimeError("no surviving replica to re-place sessions on")
        if self.policy == "prefix-affinity" and probe is not None:
            scored = [(self._affinity_tokens(i, probe), i) for i in alive]
            best = max(m for m, _ in scored)
            if best > 0:
                return self._least_loaded([i for m, i in scored if m == best])
        return self._least_loaded(alive)

    # -- event loop -----------------------------------------------------
    def step(self) -> bool:
        """One fleet step: step every alive replica that has runnable
        work.  Returns False once every session fleet-wide is done.
        The shared clock makes per-replica fast-forwards safe: it never
        rewinds, and a request whose arrival is already in the past
        executes immediately."""
        live = False
        for i, srv in enumerate(self.replicas):
            if self.dead[i]:
                continue
            if srv._fresh or srv._done_count < len(srv.sessions):
                srv.step()
                live = live or srv._done_count < len(srv.sessions)
        return live

    def run(self) -> list:
        """Drive all open sessions to completion; metrics in open order."""
        while self.step():
            pass
        return [s.metrics for s in self.sessions]

    def serve(self, prompts, max_new: int, *,
              concurrency: int | None = None,
              arrivals: list[float] | None = None,
              profile_mode: bool = False,
              slos: list | None = None) -> list:
        """Admission-controlled driver (SyneraServer.serve signature),
        routing each admission through :meth:`place`.  Returns
        per-stream DeviceMetrics in prompt order."""
        if concurrency is not None and concurrency < 1:
            raise ValueError(f"concurrency must be >= 1 or None "
                             f"(unbounded), got {concurrency}")
        first = len(self.sessions)
        idx = 0
        active: list[DeviceSession] = []
        while idx < len(prompts) or active:
            while idx < len(prompts) and (concurrency is None
                                          or len(active) < concurrency):
                arr = None if arrivals is None else arrivals[idx]
                s = self.open_session(prompts[idx], max_new,
                                      arrival_ms=arr,
                                      profile_mode=profile_mode,
                                      slo=None if slos is None
                                      else slos[idx])
                active.append(s)
                idx += 1
            self.step()
            active = [s for s in active if not s.done]
        return [s.metrics for s in self.sessions[first:]]

    # -- telemetry ------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def server_stats(self) -> ServerStats:
        """Fleet-wide view: per-replica stats folded together plus the
        router's own counters.  Latency percentiles are recomputed from
        the pooled fleet sessions (degraded streams included)."""
        per = [srv.server_stats() for srv in self.replicas]
        agg = aggregate_server_stats(
            per,
            ttfts=[s.ttft_ms for s in self.sessions if s.ttft_ms is not None],
            e2es=[s.e2e_ms for s in self.sessions if s.e2e_ms is not None])
        agg.replicas = len(self.replicas)
        agg.dead_replicas = sum(self.dead)
        agg.route_policy = self.policy
        agg.degraded_streams = self.degraded_streams
        agg.rerouted_sessions = self.rerouted_sessions
        agg.affinity_hits = self.affinity_hits
        # degraded sessions belong to no replica; fold them in here —
        # completion count, stall buckets (device-only: pure compute)
        # and latency histogram samples alike
        for s in self.sessions:
            if not (self.owner.get(id(s)) == -1 and s.done
                    and not s.cancelled):
                continue
            agg.completed_streams += 1
            if s.metrics is not None:
                tl = s.metrics.timeline
                agg.stall_wall_ms += tl.t_ms
                agg.stall_device_ms += tl.compute_ms
                agg.stall_cloud_ms += tl.cloud_ms
                agg.stall_link_ms += tl.link_ms
                agg.stall_queue_ms += tl.queue_ms
                agg.stall_batch_wait_ms += tl.batch_wait_ms
                agg.stall_swap_ms += tl.swap_ms
                agg.stall_preempted_ms += tl.preempted_ms
                agg.stall_other_ms += tl.other_ms
            if s.ttft_ms is not None:
                hist_add(agg.hist_ttft_ms, s.ttft_ms)
            if s.e2e_ms is not None:
                hist_add(agg.hist_e2e_ms, s.e2e_ms)
            if (s.ttft_ms is not None and s.e2e_ms is not None
                    and s.n_emitted > 1):
                hist_add(agg.hist_tpot_ms,
                         (s.e2e_ms - s.ttft_ms) / (s.n_emitted - 1))
        agg.queue_depth += self.ext_queue_depth
        agg.rejected_requests += self.rejected_requests
        return agg

    def stats(self) -> dict:
        """Dict view of :meth:`server_stats` (the stable extras schema)."""
        return self.server_stats().as_dict()

    def replica_stats(self, idx: int) -> dict:
        """One replica's own stats dict (per-replica ``/metrics``),
        tagged with its index and liveness."""
        srv = self.replicas[idx]          # IndexError for a bad index
        d = srv.stats()
        d["replica"] = idx
        d["dead"] = self.dead[idx]
        return d
