"""Async serving gateway: OpenAI-compatible streaming HTTP front door
over ``SyneraServer`` (see docs/serving_api.md, "HTTP gateway").

Modules:

* ``protocol`` — request parsing + ``chat.completion``/``chunk`` JSON
  and SSE framing (pure functions, unit-testable without sockets),
* ``http``     — a minimal stdlib-asyncio HTTP/1.1 server substrate
  (no third-party web framework in the container),
* ``app``      — the ``Gateway``: endpoint routing, admission +
  backpressure, the engine thread driving ``SyneraServer.step()``, and
  per-stream token queues bridging the engine thread to asyncio.
"""
from repro.serving.gateway.app import Gateway, GatewayConfig  # noqa: F401
