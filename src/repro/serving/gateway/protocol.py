"""OpenAI chat-completions wire format for the Synera gateway.

The repro serves a synthetic integer-token task (benchmarks/prepare.py),
so the "tokenizer" is the identity over decimal token ids: message
``content`` is whitespace-separated token ids (e.g. ``"5 17 23 9"``)
and completion text is emitted the same way, one ``"<id> "`` atom per
token.  Concatenating every streamed delta therefore reproduces the
full completion text byte-for-byte, and parsing it back with
:func:`parse_tokens` yields exactly the token stream an in-process
``run_synera`` call returns (identity-tested in tests/test_gateway.py).

Everything here is pure data-in/data-out — no sockets, no clocks — so
the framing is unit-testable in isolation.
"""
from __future__ import annotations

import json
import uuid
from dataclasses import dataclass, field

SSE_DONE = b"data: [DONE]\n\n"


class ProtocolError(ValueError):
    """Malformed client request (maps to HTTP 400)."""


@dataclass
class ChatRequest:
    """A validated /v1/chat/completions request."""
    prompt: list                  # concatenated message token ids
    max_tokens: int
    stream: bool
    model: str
    include_usage: bool = True
    raw: dict = field(default_factory=dict)


def parse_tokens(text: str) -> list[int]:
    """Whitespace-separated decimal token ids -> list[int]."""
    try:
        return [int(t) for t in text.split()]
    except ValueError as e:
        raise ProtocolError(
            f"message content must be whitespace-separated integer token "
            f"ids (synthetic-task vocabulary): {e}") from None


def detok(tokens) -> str:
    """Token ids -> text atoms; concatenation-safe across deltas."""
    return "".join(f"{int(t)} " for t in tokens)


def parse_chat_request(body: bytes, *, default_model: str,
                       default_max_tokens: int,
                       max_tokens_cap: int) -> ChatRequest:
    try:
        obj = json.loads(body or b"{}")
    except json.JSONDecodeError as e:
        raise ProtocolError(f"request body is not valid JSON: {e}") from None
    if not isinstance(obj, dict):
        raise ProtocolError("request body must be a JSON object")
    msgs = obj.get("messages")
    if not isinstance(msgs, list) or not msgs:
        raise ProtocolError("'messages' must be a non-empty array")
    prompt: list[int] = []
    for m in msgs:
        if not isinstance(m, dict) or "content" not in m:
            raise ProtocolError("each message needs a 'content' field")
        prompt += parse_tokens(str(m["content"]))
    if len(prompt) < 2:
        raise ProtocolError("need at least 2 prompt tokens")
    mt = obj.get("max_tokens", obj.get("max_completion_tokens",
                                       default_max_tokens))
    if not isinstance(mt, int) or mt < 1:
        raise ProtocolError("'max_tokens' must be a positive integer")
    include_usage = bool(obj.get("stream_options", {}).get(
        "include_usage", True)) if isinstance(
            obj.get("stream_options", {}), dict) else True
    return ChatRequest(prompt=prompt,
                       max_tokens=min(mt, max_tokens_cap),
                       stream=bool(obj.get("stream", False)),
                       model=str(obj.get("model", default_model)),
                       include_usage=include_usage, raw=obj)


def new_completion_id() -> str:
    return "chatcmpl-" + uuid.uuid4().hex[:24]


def usage_dict(prompt_tokens: int, completion_tokens: int) -> dict:
    return {"prompt_tokens": prompt_tokens,
            "completion_tokens": completion_tokens,
            "total_tokens": prompt_tokens + completion_tokens}


def chunk_dict(cid: str, created: int, model: str, *,
               content: str | None = None, role: str | None = None,
               finish_reason: str | None = None,
               usage: dict | None = None) -> dict:
    """One ``chat.completion.chunk``.  The delta carries ``role`` on the
    first chunk, ``content`` on token chunks, and is empty on the final
    chunk (which carries ``finish_reason`` and, per
    ``stream_options.include_usage`` semantics, ``usage``)."""
    delta: dict = {}
    if role is not None:
        delta["role"] = role
    if content is not None:
        delta["content"] = content
    out = {"id": cid, "object": "chat.completion.chunk",
           "created": created, "model": model,
           "choices": [{"index": 0, "delta": delta,
                        "finish_reason": finish_reason}]}
    if usage is not None:
        out["usage"] = usage
    return out


def completion_dict(cid: str, created: int, model: str, content: str,
                    finish_reason: str, usage: dict) -> dict:
    return {"id": cid, "object": "chat.completion", "created": created,
            "model": model,
            "choices": [{"index": 0,
                         "message": {"role": "assistant",
                                     "content": content},
                         "finish_reason": finish_reason}],
            "usage": usage}


def sse_event(data: dict) -> bytes:
    """One SSE frame: ``data: <json>\\n\\n`` (no spaces after colons in
    the JSON — keeps frames compact and byte-stable for tests)."""
    return b"data: " + json.dumps(
        data, separators=(",", ":")).encode() + b"\n\n"


def _hist_lines(name: str, h: dict, out: list) -> None:
    """Render one cumulative histogram (serving/trace.py ``hist_*``
    shape: ``{"le": (...), "buckets": [...], "sum": s}``) as standard
    Prometheus ``_bucket``/``_sum``/``_count`` samples.  ``buckets`` is
    already cumulative; its last entry is the +Inf bucket == count."""
    out.append(f"# TYPE {name} histogram")
    for le, c in zip(h["le"], h["buckets"]):
        out.append(f'{name}_bucket{{le="{float(le):g}"}} {c}')
    out.append(f'{name}_bucket{{le="+Inf"}} {h["buckets"][-1]}')
    out.append(f'{name}_sum {h["sum"]}')
    out.append(f'{name}_count {h["buckets"][-1]}')


def metrics_text(stats: dict, prefix: str = "synera_") -> str:
    """Prometheus-style text exposition of a flat stats dict: numeric
    fields become ``<prefix><name> <value>`` samples, booleans 0/1,
    ``hist_*`` dicts become real histograms (``_bucket``/``_sum``/
    ``_count``), strings become info comments."""
    lines = []
    for k, v in sorted(stats.items()):
        if isinstance(v, bool):
            lines.append(f"{prefix}{k} {int(v)}")
        elif isinstance(v, (int, float)):
            lines.append(f"{prefix}{k} {v}")
        elif (isinstance(v, dict) and "le" in v and "buckets" in v
              and "sum" in v):
            name = k[5:] if k.startswith("hist_") else k
            _hist_lines(f"{prefix}{name}", v, lines)
        else:
            lines.append(f"# {prefix}{k}: {v}")
    return "\n".join(lines) + "\n"
