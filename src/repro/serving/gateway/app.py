"""The Synera gateway: an asyncio OpenAI-compatible front door over
``SyneraServer``.

The ``server`` may equally be a ``ReplicaRouter`` (serving/router.py)
fronting N cloud replicas — it exposes the same open/cancel/step/stats
surface, every admission is then routed by the fleet policy, and
``/metrics?replica=N`` exposes one replica's own counters next to the
aggregated fleet view at ``/metrics``.

Two threads cooperate:

* the **asyncio thread** owns the sockets: it parses HTTP, enforces
  admission (429 + ``Retry-After`` past the queue cap), writes SSE
  frames, and watches each connection for client disconnect;
* the **engine thread** owns the (GIL-releasing, jax-heavy) serving
  loop: it admits accepted requests into ``SyneraServer`` sessions,
  calls ``server.step()``, and forwards tokens emitted by the device
  coroutines into per-request ``asyncio.Queue``s via
  ``loop.call_soon_threadsafe``.

Commands cross from asyncio to the engine thread through a locked inbox
(open / cancel); tokens and completion events cross back through the
per-stream queues.  Cancellation (explicit or disconnect-driven) lands
in ``SyneraServer.cancel``, which purges the stream's scheduler
requests and releases its slot row, blocks, prefix refs and swap state
— the resource-leak regression tests poll ``pool_stats`` back to
baseline after mid-stream disconnects.

Clock modes (see ``serving/link.py``):

* ``SimClock`` — modeled time only; useful for tests that want
  deterministic schedules over a real socket.
* ``RealClock(pace=False)`` (the default for ``serve.py --http``) —
  wall-clock serving: requests are served as fast as the host allows,
  arrivals are clamped to "now", and the modeled costs accumulate into
  ``clock.modeled_ms`` for the modeled-vs-real cross-check.
* ``RealClock(pace=True)`` — cloud iterations and idle gaps *sleep*
  through their modeled cost, so wall-clock latencies track the modeled
  schedule (real >= modeled; the excess is host compute + overhead).
"""
from __future__ import annotations

import asyncio
import json
import sys
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass

from repro.serving.gateway import http as H
from repro.serving.gateway import protocol as P


@dataclass
class GatewayConfig:
    host: str = "127.0.0.1"
    port: int = 0                  # 0 = ephemeral (bound port on .port)
    model_name: str = "synera-tiny"
    max_new_default: int = 32      # max_tokens when the client omits it
    max_new_cap: int = 256         # hard per-request cap
    max_active: int = 8            # sessions open in the server at once
    queue_cap: int = 8             # accepted-but-not-opened beyond that
    retry_after_s: int = 1         # Retry-After on 429
    idle_tick_s: float = 0.02      # engine poll interval when idle
    stats_refresh_s: float = 0.25  # /metrics snapshot staleness bound


class _Stream:
    """One accepted chat-completions request, shared between threads."""
    __slots__ = ("req", "loop", "queue", "session", "dead")

    def __init__(self, req: P.ChatRequest, loop):
        self.req = req
        self.loop = loop
        self.queue: asyncio.Queue = asyncio.Queue()
        self.session = None            # DeviceSession once opened
        self.dead = False              # client gone; drop further pushes

    def push(self, item) -> None:
        """Engine thread -> asyncio queue (thread-safe, never blocks)."""
        if self.dead:
            return
        try:
            self.loop.call_soon_threadsafe(self.queue.put_nowait, item)
        except RuntimeError:
            self.dead = True           # loop already closed


class Gateway:
    """HTTP front door over one ``SyneraServer``.

    ``start()`` launches the engine + asyncio threads and returns once
    the socket is bound (``.port`` holds the real port); ``close()``
    tears both down.  ``run_forever()`` is the blocking CLI entry.
    """

    def __init__(self, server, config: GatewayConfig | None = None):
        self.server = server
        self.cfg = config or GatewayConfig()
        self.host = self.cfg.host
        self.port = self.cfg.port
        self._lock = threading.Lock()
        self._n_queued = 0             # accepted, waiting for a session
        self._n_open = 0               # sessions open, not finished
        self._inbox: deque = deque()   # ("open"|"cancel", _Stream)
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._ready = threading.Event()
        self._pending: deque = deque()  # engine-thread-owned admit queue
        self._active: list[_Stream] = []
        self._stats = server.stats()
        self._stats_t = time.monotonic()
        self._loop: asyncio.AbstractEventLoop | None = None
        self._threads: list[threading.Thread] = []

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "Gateway":
        t_eng = threading.Thread(target=self._engine_loop,
                                 name="gw-engine", daemon=True)
        t_http = threading.Thread(target=lambda: asyncio.run(self._amain()),
                                  name="gw-http", daemon=True)
        self._threads = [t_eng, t_http]
        t_eng.start()
        t_http.start()
        if not self._ready.wait(timeout=30):
            raise RuntimeError("gateway failed to bind within 30s")
        return self

    def close(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._loop is not None:
            try:
                self._loop.call_soon_threadsafe(lambda: None)  # wake loop
            except RuntimeError:
                pass
        for t in self._threads:
            t.join(timeout=10)

    def run_forever(self) -> None:
        self.start()
        print(f"synera gateway listening on http://{self.host}:{self.port} "
              f"(queue_cap={self.cfg.queue_cap}, "
              f"max_active={self.cfg.max_active})", flush=True)
        try:
            while not self._stop.is_set():
                time.sleep(0.5)
        except KeyboardInterrupt:
            pass
        finally:
            self.close()

    # -- engine thread --------------------------------------------------
    def _submit(self, cmd) -> None:
        with self._lock:
            self._inbox.append(cmd)
        self._wake.set()

    def _drain_inbox(self) -> None:
        while True:
            with self._lock:
                if not self._inbox:
                    return
                kind, st = self._inbox.popleft()
            if kind == "open":
                self._pending.append(st)
            elif kind == "cancel":
                self._cancel(st)
            elif kind == "stats":
                # fresh stats computed on the engine thread: server state
                # is only ever touched here, so /metrics never races a
                # step() in progress.  ridx selects one replica's view
                # behind a ReplicaRouter (/metrics?replica=N).
                loop, fut, ridx = st
                if ridx is None:
                    self._refresh_stats(force=True)
                    snap = dict(self._stats)
                else:
                    try:
                        snap = self.server.replica_stats(int(ridx))
                    except (AttributeError, IndexError, ValueError):
                        snap = {"error": f"no replica {ridx!r}"}
                try:
                    loop.call_soon_threadsafe(
                        lambda f=fut, s=snap:
                        f.done() or f.set_result(s))
                except RuntimeError:
                    pass
            elif kind == "trace":
                # trace snapshot rendered on the engine thread for the
                # same reason as stats: the tracer's buffers are only
                # ever appended to there, so /v1/traces never races a
                # step() in progress
                loop, fut = st
                tr = getattr(self.server, "tracer", None)
                if tr is not None and getattr(tr, "enabled", False):
                    snap = tr.to_dict()
                    snap["enabled"] = True
                else:
                    snap = {"enabled": False, "traceEvents": [],
                            "displayTimeUnit": "ms"}
                try:
                    loop.call_soon_threadsafe(
                        lambda f=fut, s=snap:
                        f.done() or f.set_result(s))
                except RuntimeError:
                    pass

    def _cancel(self, st: _Stream) -> None:
        st.dead = True
        if st.session is None:
            try:
                self._pending.remove(st)
            except ValueError:
                return                 # already opened+finished, or unknown
            with self._lock:
                self._n_queued -= 1
            return
        if self.server.cancel(st.session):
            with self._lock:
                self._n_open -= 1
            try:
                self._active.remove(st)
            except ValueError:
                pass

    def _open(self, st: _Stream) -> None:
        st.session = self.server.open_session(
            st.req.prompt, st.req.max_tokens,
            emit=lambda toks, t_ms, _st=st: _st.push(("tok", list(toks))))
        with self._lock:
            self._n_queued -= 1
            self._n_open += 1
        self._active.append(st)

    def _finish(self, st: _Stream) -> None:
        self._active.remove(st)
        with self._lock:
            self._n_open -= 1
        st.push(("done", st.session))

    def _refresh_stats(self, force: bool = False) -> None:
        now = time.monotonic()
        if force or now - self._stats_t >= self.cfg.stats_refresh_s:
            self._stats = self.server.stats()
            self._stats_t = now

    def _engine_loop(self) -> None:
        srv = self.server
        while not self._stop.is_set():
            try:
                self._drain_inbox()
                while self._pending and self._n_open < self.cfg.max_active:
                    self._open(self._pending.popleft())
                srv.ext_queue_depth = len(self._pending)
                if not self._active:
                    self._refresh_stats()
                    self._wake.wait(self.cfg.idle_tick_s)
                    self._wake.clear()
                    continue
                srv.step()
                for st in [s for s in self._active if s.session.done]:
                    self._finish(st)
                self._refresh_stats(force=not self._active)
            except Exception:
                # a serving-loop failure must not strand open sockets:
                # fail every in-flight stream, keep accepting (each new
                # request sees a fresh attempt / its own error)
                msg = traceback.format_exc()
                print(f"gateway engine error:\n{msg}",
                      file=sys.stderr, flush=True)
                for st in list(self._active):
                    try:
                        srv.cancel(st.session)
                    except Exception:
                        pass
                    self._active.remove(st)
                    with self._lock:
                        self._n_open -= 1
                    st.push(("err", msg.strip().splitlines()[-1]))

    # -- asyncio thread -------------------------------------------------
    async def _amain(self) -> None:
        self._loop = asyncio.get_running_loop()
        server = await asyncio.start_server(self._client, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        self._ready.set()
        async with server:
            while not self._stop.is_set():
                await asyncio.sleep(0.1)

    async def _client(self, reader, writer) -> None:
        # keep-alive loop: one connection carries exchanges until the
        # client closes, asks to close, or an exchange requires it
        # (SSE streams, disconnects, framing errors)
        try:
            first = b""
            while not self._stop.is_set():
                try:
                    hreq = await H.read_request(reader, first=first)
                except (H.BadRequest, asyncio.IncompleteReadError) as e:
                    writer.write(H.response(400, json.dumps(
                        {"error": {"message": str(e)}}).encode()))
                    return
                if hreq is None:
                    return
                keep = H.wants_keep_alive(hreq.headers)
                first = await self._route(hreq, reader, writer, keep)
                if first is None:
                    return
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            try:
                if writer.can_write_eof():
                    writer.write_eof()
            except (OSError, RuntimeError):
                pass
            writer.close()

    async def _route(self, hreq: H.HTTPRequest, reader, writer,
                     keep: bool) -> bytes | None:
        """Handle one exchange.  Returns pushback bytes for the next
        ``read_request`` (b"" normally) to keep the connection open, or
        None to close it."""
        if hreq.path == "/v1/chat/completions":
            if hreq.method != "POST":
                writer.write(H.response(405, b'{"error":"POST only"}',
                                        keep_alive=keep))
            else:
                nxt = await self._chat(hreq, reader, writer, keep)
                await writer.drain()
                return nxt
        elif hreq.path == "/v1/models":
            body = json.dumps({"object": "list", "data": [
                {"id": self.cfg.model_name, "object": "model",
                 "owned_by": "synera-repro"}]}).encode()
            writer.write(H.response(200, body, keep_alive=keep))
        elif hreq.path == "/healthz":
            with self._lock:
                body = json.dumps({"status": "ok", "active": self._n_open,
                                   "queued": self._n_queued}).encode()
            writer.write(H.response(200, body, keep_alive=keep))
        elif hreq.path == "/metrics":
            ridx = hreq.query.get("replica")
            loop = asyncio.get_running_loop()
            fut = loop.create_future()
            self._submit(("stats", (loop, fut, ridx)))
            try:
                stats = await asyncio.wait_for(fut, timeout=10)
            except asyncio.TimeoutError:
                if ridx is not None:
                    stats = {"error": "engine busy; retry"}
                else:
                    stats = dict(self._stats)  # engine wedged: last snapshot
            if "error" in stats:
                # unknown replica index, or a single-server gateway asked
                # for a per-replica view (no ReplicaRouter in front)
                writer.write(H.response(
                    404, json.dumps({"error": {
                        "message": stats["error"]}}).encode(),
                    keep_alive=keep))
                await writer.drain()
                return b"" if keep else None
            if ridx is None:
                # gateway-level gauges only make sense on the fleet view
                with self._lock:
                    stats["gateway_active"] = self._n_open
                    stats["gateway_queued"] = self._n_queued
            if hreq.query.get("format") == "json":
                writer.write(H.response(200, json.dumps(stats).encode(),
                                        keep_alive=keep))
            else:
                writer.write(H.response(
                    200, P.metrics_text(stats).encode(),
                    content_type="text/plain; version=0.0.4",
                    keep_alive=keep))
        elif hreq.path == "/v1/traces":
            # Chrome/Perfetto trace-event snapshot of everything the
            # tracer has recorded so far; {"enabled": false} when the
            # gateway was started without --trace
            loop = asyncio.get_running_loop()
            fut = loop.create_future()
            self._submit(("trace", (loop, fut)))
            try:
                trace = await asyncio.wait_for(fut, timeout=10)
            except asyncio.TimeoutError:
                trace = {"enabled": False, "traceEvents": [],
                         "error": "engine busy; retry"}
            writer.write(H.response(200, json.dumps(trace).encode(),
                                    keep_alive=keep))
        else:
            writer.write(H.response(404, b'{"error":"not found"}',
                                    keep_alive=keep))
        await writer.drain()
        return b"" if keep else None

    # -- chat completions ----------------------------------------------
    async def _chat(self, hreq: H.HTTPRequest, reader, writer,
                    keep: bool) -> bytes | None:
        try:
            req = P.parse_chat_request(
                hreq.body, default_model=self.cfg.model_name,
                default_max_tokens=self.cfg.max_new_default,
                max_tokens_cap=self.cfg.max_new_cap)
        except P.ProtocolError as e:
            writer.write(H.response(400, json.dumps(
                {"error": {"message": str(e),
                           "type": "invalid_request_error"}}).encode(),
                keep_alive=keep))
            await writer.drain()
            return b"" if keep else None
        # admission: the system holds at most max_active running plus
        # queue_cap waiting requests.  Bounding the *total* (not just
        # the wait queue) keeps a cold burst from queueing unboundedly
        # before the engine has opened its first session.  Counted under
        # the lock so concurrent handlers + the engine thread agree.
        with self._lock:
            saturated = (self._n_open + self._n_queued
                         >= self.cfg.max_active + self.cfg.queue_cap)
            if not saturated:
                self._n_queued += 1
        if saturated:
            self.server.rejected_requests += 1
            writer.write(H.response(
                429, json.dumps({"error": {
                    "message": f"server saturated: {self.cfg.max_active} "
                               f"active streams and a full wait queue "
                               f"({self.cfg.queue_cap}); retry later",
                    "type": "rate_limit_error"}}).encode(),
                keep_alive=keep,
                extra_headers={"Retry-After": str(self.cfg.retry_after_s)}))
            await writer.drain()
            return b"" if keep else None
        st = _Stream(req, asyncio.get_running_loop())
        self._submit(("open", st))
        # per-stream disconnect watch: any bytes (or EOF) while this
        # stream is in flight = the client went away (no pipelining)
        eof_task = asyncio.ensure_future(reader.read(1))
        try:
            if req.stream:
                await self._chat_stream(st, writer, eof_task)
                return None        # SSE body ends at EOF: always close
            done = await self._chat_full(st, writer, eof_task, keep)
            if done and keep:
                return await self._harvest(eof_task)
            return None
        except (ConnectionResetError, BrokenPipeError):
            self._disconnect(st)
            return None
        finally:
            if not eof_task.done():
                eof_task.cancel()

    @staticmethod
    async def _harvest(eof_task) -> bytes | None:
        """Retire the disconnect watcher after a completed keep-alive
        exchange.  If it already consumed a byte, that byte is the start
        of the next request line (push it back); a completed empty read
        means the client hit EOF (close).  Must *await* the cancelled
        task: until cancellation lands, the watcher still owns the
        stream reader and the next ``readline`` would race it."""
        eof_task.cancel()
        try:
            data = await eof_task
        except asyncio.CancelledError:
            return b""                 # watcher retired without reading
        except Exception:
            return None
        return data if data else None  # byte = next request; b"" = EOF

    async def _next_event(self, st: _Stream, eof_task):
        """Next queue item, or None if the client disconnected first."""
        get_task = asyncio.ensure_future(st.queue.get())
        done, _ = await asyncio.wait({get_task, eof_task},
                                     return_when=asyncio.FIRST_COMPLETED)
        if get_task in done:
            return get_task.result()
        get_task.cancel()
        return None

    def _disconnect(self, st: _Stream) -> None:
        st.dead = True
        self._submit(("cancel", st))

    async def _chat_stream(self, st: _Stream, writer, eof_task) -> None:
        req = st.req
        cid, created = P.new_completion_id(), int(time.time())
        writer.write(H.SSE_HEADER)
        writer.write(P.sse_event(P.chunk_dict(cid, created, req.model,
                                              role="assistant")))
        await writer.drain()
        n_tok = 0
        while True:
            ev = await self._next_event(st, eof_task)
            if ev is None:
                self._disconnect(st)
                return
            kind, payload = ev
            if kind == "tok":
                n_tok += len(payload)
                try:
                    writer.write(P.sse_event(P.chunk_dict(
                        cid, created, req.model,
                        content=P.detok(payload))))
                    await writer.drain()
                except (ConnectionResetError, BrokenPipeError):
                    self._disconnect(st)
                    return
            elif kind == "done":
                finish = "length" if n_tok >= req.max_tokens else "stop"
                usage = (P.usage_dict(len(req.prompt), n_tok)
                         if req.include_usage else None)
                writer.write(P.sse_event(P.chunk_dict(
                    cid, created, req.model, finish_reason=finish,
                    usage=usage)))
                writer.write(P.SSE_DONE)
                await writer.drain()
                return
            else:  # "err"
                writer.write(P.sse_event(
                    {"error": {"message": str(payload)}}))
                await writer.drain()
                return

    async def _chat_full(self, st: _Stream, writer, eof_task,
                         keep: bool) -> bool:
        """Non-streamed completion.  Returns True when the exchange
        finished cleanly and the connection may be kept alive."""
        req = st.req
        cid, created = P.new_completion_id(), int(time.time())
        toks: list[int] = []
        while True:
            ev = await self._next_event(st, eof_task)
            if ev is None:
                self._disconnect(st)
                return False
            kind, payload = ev
            if kind == "tok":
                toks += payload
            elif kind == "done":
                finish = ("length" if len(toks) >= req.max_tokens
                          else "stop")
                body = P.completion_dict(
                    cid, created, req.model, P.detok(toks).rstrip(),
                    finish, P.usage_dict(len(req.prompt), len(toks)))
                writer.write(H.response(200, json.dumps(body).encode(),
                                        keep_alive=keep))
                await writer.drain()
                return True
            else:  # "err"
                writer.write(H.response(500, json.dumps(
                    {"error": {"message": str(payload)}}).encode()))
                await writer.drain()
                return False
