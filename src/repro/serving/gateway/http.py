"""Minimal asyncio HTTP/1.1 substrate for the gateway.

The container has no third-party web stack (no aiohttp/FastAPI/uvicorn),
so the gateway speaks HTTP directly over asyncio streams.  Scope is
deliberately tiny — exactly what the gateway and its bench client need:

* request parsing (request line, headers, body framed by Content-Length
  or ``Transfer-Encoding: chunked``; bodies are capped either way),
* fixed responses and SSE streaming responses,
* HTTP/1.1 keep-alive: fixed responses carry ``Connection: keep-alive``
  unless the client asked to close, so one connection can carry many
  exchanges (pipelining is not supported — bytes arriving while a chat
  stream is in flight are treated as a client disconnect).  SSE
  streaming responses always close: the stream *is* the response body,
  so its end is signalled by EOF.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from urllib.parse import parse_qs, urlsplit

MAX_BODY_BYTES = 1 << 20
MAX_HEADER_LINES = 64

REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 429: "Too Many Requests",
           500: "Internal Server Error"}


@dataclass
class HTTPRequest:
    method: str
    path: str                     # path only, query stripped
    query: dict = field(default_factory=dict)   # first value per key
    headers: dict = field(default_factory=dict)  # lower-cased names
    body: bytes = b""


class BadRequest(ValueError):
    pass


async def read_request(reader, first: bytes = b"") -> HTTPRequest | None:
    """Parse one HTTP/1.1 request; None on immediate EOF (client went
    away between connect and send).  Raises BadRequest on malformed or
    oversized input.  ``first`` is prepended to the request line — the
    keep-alive loop uses it to push back bytes its disconnect watcher
    consumed between exchanges."""
    line = first + await reader.readline()
    if not line:
        return None
    try:
        method, target, _version = line.decode("ascii").split()
    except ValueError:
        raise BadRequest(f"malformed request line: {line[:80]!r}")
    headers: dict[str, str] = {}
    for _ in range(MAX_HEADER_LINES):
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, val = h.decode("latin1").partition(":")
        headers[name.strip().lower()] = val.strip()
    else:
        raise BadRequest("too many header lines")
    if "chunked" in headers.get("transfer-encoding", "").lower():
        body = await _read_chunked(reader)
    else:
        try:
            clen = int(headers.get("content-length", "0"))
        except ValueError:
            raise BadRequest("bad Content-Length")
        if not 0 <= clen <= MAX_BODY_BYTES:
            raise BadRequest(f"body too large ({clen} bytes)")
        body = await reader.readexactly(clen) if clen else b""
    parts = urlsplit(target)
    query = {k: v[0] for k, v in parse_qs(parts.query).items()}
    return HTTPRequest(method=method.upper(), path=parts.path, query=query,
                       headers=headers, body=body)


async def _read_chunked(reader) -> bytes:
    """Decode a ``Transfer-Encoding: chunked`` request body (RFC 9112
    §7.1): ``size-in-hex[;ext] CRLF data CRLF`` frames until a zero-size
    chunk, then trailer lines up to a blank line.  Trailers are read and
    discarded; the cumulative body is capped at ``MAX_BODY_BYTES`` so a
    client cannot stream unbounded data by never sending the terminal
    chunk."""
    body = bytearray()
    while True:
        line = await reader.readline()
        if not line.endswith(b"\n"):
            raise BadRequest("truncated chunk size line")
        size_s = line.strip().split(b";", 1)[0]   # drop chunk extensions
        try:
            size = int(size_s, 16)
        except ValueError:
            raise BadRequest(f"bad chunk size: {size_s[:20]!r}")
        if size < 0:
            raise BadRequest(f"bad chunk size: {size_s[:20]!r}")
        if size == 0:
            break
        if len(body) + size > MAX_BODY_BYTES:
            raise BadRequest(f"chunked body too large "
                             f"(> {MAX_BODY_BYTES} bytes)")
        body += await reader.readexactly(size)
        if await reader.readexactly(2) != b"\r\n":
            raise BadRequest("chunk data not CRLF-terminated")
    for _ in range(MAX_HEADER_LINES):
        t = await reader.readline()
        if t in (b"\r\n", b"\n", b""):
            return bytes(body)
    raise BadRequest("too many trailer lines")


def response(status: int, body: bytes, *,
             content_type: str = "application/json",
             keep_alive: bool = False,
             extra_headers: dict | None = None) -> bytes:
    head = [f"HTTP/1.1 {status} {REASONS.get(status, 'Unknown')}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            f"Connection: {'keep-alive' if keep_alive else 'close'}"]
    for k, v in (extra_headers or {}).items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


def wants_keep_alive(headers: dict) -> bool:
    """HTTP/1.1 default: keep the connection open unless the client
    sent ``Connection: close``."""
    return headers.get("connection", "").lower() != "close"


SSE_HEADER = (b"HTTP/1.1 200 OK\r\n"
              b"Content-Type: text/event-stream\r\n"
              b"Cache-Control: no-cache\r\n"
              b"Connection: close\r\n\r\n")
