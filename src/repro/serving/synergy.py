"""End-to-end device-cloud orchestration (Fig 8) and the paper's four
baselines (§6.1): Edge-centric, Cloud-centric, Hybrid [9], EdgeFM-LLM.

``CloudClient`` is one device stream's handle on the cloud runtime.  It
exposes non-blocking submission (``prefill_async`` / ``verify_async``)
used by the multi-tenant ``SyneraServer`` event loop
(serving/server.py), plus the legacy blocking facade (``prefill`` /
``verify``) that spins the scheduler until its own request completes —
kept for single-stream baselines such as the cloud-centric decode loop.

``run_synera`` and friends are thin wrappers over the server: with the
default ``concurrency=1`` they reproduce the original strictly
sequential semantics (identical token streams and per-stream
timelines); with ``concurrency=N`` the scheduler genuinely packs verify
chunks from multiple streams per iteration.  Token streams are real
model outputs; only wall-clock is modeled (see serving/link.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.verifier import VerifyResult
from repro.serving.device import DeviceMetrics, DeviceRuntime
from repro.serving.engine import CloudEngine
from repro.serving.link import CloudLatencyModel, CostModel, LinkModel
from repro.serving.scheduler import (PrefillRequest, VerificationAwareScheduler,
                                     VerifyRequest)


class CloudClient:
    """One device stream's view of the cloud runtime."""

    def __init__(self, scheduler: VerificationAwareScheduler,
                 sampling: str = "greedy", slo=None):
        self.sched = scheduler
        self.sampling = sampling
        self.slo = slo              # StreamSLO for slo-aware preemption
        self.slot = None
        self.last_fed_tokens = 0
        self.total_fed_tokens = 0   # generation-phase feeds only
        self.prefill_tokens = 0

    # -- non-blocking submission (SyneraServer event loop) -------------
    def prefill_async(self, prompt: list[int], arrival_ms: float = 0.0) -> int:
        """Queue the prompt prefill; returns the request id.  The slot is
        assigned when the scheduler emits ``prefill_done`` (see
        ``on_event``)."""
        rid = self.sched.next_req_id()
        self.sched.submit_prefill(PrefillRequest(
            rid, np.asarray(prompt), arrival_ms=arrival_ms, slo=self.slo))
        # prompt prefill tracked separately from generation-phase feeds
        self.prefill_tokens = len(prompt)
        return rid

    def verify_async(self, seq: list[int], draft: list[int], dists,
                     arrival_ms: float = 0.0) -> int:
        """Queue a verification request; returns the request id.

        ``seq`` is the device's accepted stream (prompt + output).
        Tokens beyond the cloud's cached frontier are the uncached
        device-accepted tokens of the partial prefill (§3.4)."""
        uncached = np.asarray(seq[self.frontier():], np.int64)
        self.last_fed_tokens = len(uncached) + len(draft)
        self.total_fed_tokens += self.last_fed_tokens
        rid = self.sched.next_req_id()
        # the full accepted stream rides along so a paged-pool preemption
        # can restart the request as a from-scratch partial prefill
        self.sched.submit_verify(VerifyRequest(
            rid, self.slot, uncached=uncached,
            draft=np.asarray(draft, np.int64),
            q_sparse=[(d.idx, d.val) for d in dists],
            sampling=self.sampling, arrival_ms=arrival_ms,
            seq=np.asarray(seq, np.int64)))
        return rid

    def on_event(self, ev) -> None:
        """Apply a scheduler completion event for one of our requests."""
        if ev.kind == "prefill_done":
            self.slot = ev.slot

    def frontier(self) -> int:
        return int(self.sched.cloud_len[self.slot])

    def release(self):
        if self.slot is not None:
            self.sched.release_slot(self.slot)
            self.slot = None

    # -- legacy blocking facade ----------------------------------------
    def _run_until(self, req_id: int, kind: str):
        while True:
            t_before = self.sched.sim_ms
            evs = self.sched.run_iteration()
            for ev in evs:
                if ev.req_id == req_id and ev.kind == kind:
                    return ev
            if not self.sched.has_work():
                raise RuntimeError("scheduler idle before completion")
            if not evs and self.sched.sim_ms == t_before:
                # nothing executed, nothing to fast-forward to: only an
                # external action (slot release) could unblock — a bare
                # blocking client has none coming, so fail loudly
                raise RuntimeError(
                    "blocking CloudClient stalled (request blocked with "
                    "no slot free and no other work); use SyneraServer "
                    "for oversubscribed multi-stream serving")

    def prefill(self, prompt: list[int], arrival_ms: float = 0.0):
        rid = self.prefill_async(prompt, arrival_ms=arrival_ms)
        t0 = self.sched.sim_ms
        ev = self._run_until(rid, "prefill_done")
        self.on_event(ev)
        # elapsed from when the request could first be served: the clock
        # may fast-forward to arrival_ms if the scheduler was idle
        return self.sched.sim_ms - max(t0, arrival_ms)

    def verify(self, seq: list[int], draft: list[int], dists,
               arrival_ms: float = 0.0) -> tuple[VerifyResult, float]:
        rid = self.verify_async(seq, draft, dists, arrival_ms=arrival_ms)
        t0 = self.sched.sim_ms
        ev = self._run_until(rid, "verify_done")
        return ev.result, self.sched.sim_ms - max(t0, arrival_ms)


# ---------------------------------------------------------------------------
# End-to-end runs
# ---------------------------------------------------------------------------

@dataclass
class RunResult:
    outputs: list = field(default_factory=list)     # list[list[int]]
    metrics: list = field(default_factory=list)     # list[DeviceMetrics]
    tbt_ms: float = 0.0
    cloud_token_frac: float = 0.0
    cloud_fed_frac: float = 0.0
    cost: float = 0.0
    extras: dict = field(default_factory=dict)

    def summarize(self, cost_model: CostModel):
        tbts = [m.tbt_ms for m in self.metrics]
        self.tbt_ms = float(np.mean(tbts)) if tbts else 0.0
        fracs = [m.cloud_token_frac for m in self.metrics]
        self.cloud_token_frac = float(np.mean(fracs)) if fracs else 0.0
        fed = [m.n_cloud_fed_tokens / max(len(m.tokens), 1)
               for m in self.metrics]
        self.cloud_fed_frac = float(np.mean(fed)) if fed else 0.0
        # paper §6.1: c = (1/Pf) x T x W with W = fraction of tokens
        # whose generation involved the cloud (verified tokens); the
        # generation-phase fed-token count is kept as a diagnostic
        self.cost = cost_model.cost(self.tbt_ms, self.cloud_token_frac)
        return self


def run_synera(device: DeviceRuntime, engine: CloudEngine,
               prompts: list[list[int]], max_new: int, *,
               sampling: str = "greedy",
               cost_model: CostModel | None = None,
               profile_mode: bool = False,
               chunk: int = 32,
               concurrency: int | None = 1,
               arrivals: list[float] | None = None,
               latency: CloudLatencyModel | None = None,
               preempt_policy: str | None = None,
               slos: list | None = None,
               trace: bool = False) -> RunResult:
    """Serve ``prompts`` through the Synera pipeline.

    ``concurrency=1`` (default) runs streams strictly one after another
    (the original blocking semantics); ``concurrency=N`` (or ``None``
    for unbounded) lets the SyneraServer event loop interleave up to N
    device streams over the shared cloud engine, so verify iterations
    pack chunks from multiple slots.  ``arrivals`` optionally gives each
    stream an absolute arrival offset (ms) on the shared clock;
    ``preempt_policy`` / ``slos`` select the eviction victim policy and
    attach per-stream latency budgets (serving/swap.py).  ``trace=True``
    attaches a ``Tracer`` on the shared clock (``extras['tracer']``) —
    token streams are byte-identical either way.
    """
    from repro.serving.link import SimClock
    from repro.serving.server import SyneraServer
    from repro.serving.trace import Tracer
    clock = SimClock()
    tracer = Tracer(clock) if trace else None
    server = SyneraServer(device, engine, chunk=chunk, sampling=sampling,
                          latency=latency, preempt_policy=preempt_policy,
                          clock=clock, tracer=tracer)
    metrics = server.serve(prompts, max_new, concurrency=concurrency,
                           arrivals=arrivals, profile_mode=profile_mode,
                           slos=slos)
    res = RunResult()
    for m in metrics:
        res.outputs.append(m.tokens)
        res.metrics.append(m)
    res.extras["scheduler"] = server.stats()
    if tracer is not None:
        res.extras["tracer"] = tracer
    return res.summarize(cost_model or CostModel())


def run_synera_fleet(device: DeviceRuntime, engines: list[CloudEngine],
                     prompts: list[list[int]], max_new: int, *,
                     policy: str = "least-loaded",
                     replica_queue_cap: int = 0,
                     sampling: str = "greedy",
                     cost_model: CostModel | None = None,
                     chunk: int = 32,
                     concurrency: int | None = 1,
                     arrivals: list[float] | None = None,
                     latency: CloudLatencyModel | None = None,
                     preempt_policy: str | None = None,
                     slos: list | None = None,
                     trace: bool = False) -> RunResult:
    """Serve ``prompts`` across a fleet of cloud replicas behind a
    ``ReplicaRouter`` (serving/router.py).

    One ``SyneraServer`` per engine, all on one shared clock and one
    device runtime; each admission is placed by ``policy`` (round-robin
    / least-loaded / prefix-affinity).  Placement must never change
    content: greedy token streams are byte-identical to the
    single-engine ``run_synera`` run regardless of policy or replica
    count.  ``replica_queue_cap`` bounds live sessions per replica —
    when every replica is past it, new streams degrade to device-only
    generation instead of being rejected.  ``extras['scheduler']`` is
    the fleet-aggregated stats dict; ``extras['replicas']`` the
    per-replica views."""
    from repro.serving.link import SimClock
    from repro.serving.router import ReplicaRouter
    from repro.serving.server import build_fleet
    from repro.serving.trace import Tracer
    clock = SimClock()
    tracer = Tracer(clock) if trace else None
    servers = build_fleet(device, engines, chunk=chunk, sampling=sampling,
                          latency=latency, preempt_policy=preempt_policy,
                          clock=clock, tracer=tracer)
    router = ReplicaRouter(servers, policy=policy,
                           replica_queue_cap=replica_queue_cap)
    metrics = router.serve(prompts, max_new, concurrency=concurrency,
                           arrivals=arrivals, slos=slos)
    res = RunResult()
    for m in metrics:
        res.outputs.append(m.tokens)
        res.metrics.append(m)
    res.extras["scheduler"] = router.stats()
    res.extras["replicas"] = [router.replica_stats(i)
                              for i in range(router.n_replicas)]
    if tracer is not None:
        res.extras["tracer"] = tracer
    return res.summarize(cost_model or CostModel())


def run_edge_centric(device: DeviceRuntime, prompts, max_new,
                     cost_model=None) -> RunResult:
    res = RunResult()
    for prompt in prompts:
        m = device.generate(prompt, max_new, cloud=None)
        res.outputs.append(m.tokens)
        res.metrics.append(m)
    return res.summarize(cost_model or CostModel())


def run_cloud_centric(engine: CloudEngine, prompts, max_new, *,
                      link: LinkModel | None = None,
                      latency: CloudLatencyModel | None = None,
                      cost_model=None, sampling: str = "greedy") -> RunResult:
    """All queries offloaded; the cloud decodes every token (continuous
    batching decode iterations).  TBT includes the per-token downlink."""
    link = link or LinkModel()
    res = RunResult()
    sched = VerificationAwareScheduler(engine,
                                       latency=latency or CloudLatencyModel())
    B = engine.max_slots
    for prompt in prompts:
        client = CloudClient(sched, sampling=sampling)
        t0 = sched.sim_ms
        client.prefill(prompt)
        slot = client.slot
        out = []
        last = int(np.argmax(sched.last_row[slot]))
        out.append(last)
        tokens = np.zeros((B, 1), np.int32)
        positions = np.full((B, 1), -1, np.int32)
        while len(out) < max_new:
            tokens[slot, 0] = last
            positions[slot, 0] = len(prompt) + len(out) - 1
            rows = sched.decode_iteration(tokens, positions)
            last = int(rows.token_id[slot])
            out.append(last)
        m = DeviceMetrics()
        m.tokens = out[:max_new]
        m.n_cloud_tokens = len(m.tokens)
        m.n_cloud_fed_tokens = len(out)
        # time: cloud iterations + per-token downlink
        cloud_ms = sched.sim_ms - t0
        comm_ms = (link.transfer_ms(4 * len(prompt) + 32)
                   + len(out) * link.transfer_ms(36))
        m.timeline.advance(cloud_ms, "compute")
        m.timeline.advance(comm_ms, "comm")
        res.outputs.append(m.tokens)
        res.metrics.append(m)
        client.release()
    return res.summarize(cost_model or CostModel())


def run_hybrid(device: DeviceRuntime, engine: CloudEngine, prompts, max_new,
               *, cost_model=None, chunk: int = 32,
               concurrency: int | None = 1,
               arrivals: list[float] | None = None,
               preempt_policy: str | None = None,
               trace: bool = False) -> RunResult:
    """Hybrid [9]: SLM-LLM token-level offloading by *confidence only*
    (no importance, no PI, no early exit)."""
    from repro.core.offload import OffloadPolicy
    dev = DeviceRuntime(
        device.cfg, device.params, s_max=device.s_max, gamma=device.gamma,
        policy=OffloadPolicy(c_th=device.policy.c_th, mode="conf"),
        sampling=device.sampling, latency=device.latency, link=device.link,
        use_early_exit=False, use_pi=False, alpha=device.alpha,
        wire_vocab=device.wire_vocab)
    return run_synera(dev, engine, prompts, max_new, cost_model=cost_model,
                      chunk=chunk, concurrency=concurrency,
                      arrivals=arrivals, preempt_policy=preempt_policy,
                      trace=trace)


def run_edgefm(device: DeviceRuntime, engine: CloudEngine, prompts, max_new,
               *, ppl_threshold: float = 0.0, cost_model=None,
               link: LinkModel | None = None) -> RunResult:
    """EdgeFM [38] adapted to LLMs (§6.1): *input-level* offloading —
    high-perplexity prompts go entirely to the cloud, the rest stay
    entirely on the device."""
    ppls = [device.perplexity(p) for p in prompts]
    thr = ppl_threshold or float(np.median(ppls))
    res = RunResult()
    for prompt, ppl in zip(prompts, ppls):
        if ppl > thr:
            r = run_cloud_centric(engine, [prompt], max_new, link=link)
            res.outputs.append(r.outputs[0])
            res.metrics.append(r.metrics[0])
        else:
            m = device.generate(prompt, max_new, cloud=None)
            res.outputs.append(m.tokens)
            res.metrics.append(m)
    return res.summarize(cost_model or CostModel())
