"""Cloud execution engine: slot-based continuous batching on fixed-shape
jit-compiled steps (the TPU-idiomatic equivalent of vLLM's engine; see
DESIGN.md §2).

The engine is *mechanism only*: it owns the KV/SSM cache pytree and
exposes fixed-shape ``feed`` (chunked partial prefill over any slots) and
``decode`` steps.  All batching *policy* lives in
``serving/scheduler.py`` (Algorithm 1 of the paper).

Ragged per-slot chunks are padded to the iteration width; padded entries
carry position -1, which ``cache_write`` drops (never pollutes the
cache).  Chunk widths are bucketed to powers of two to bound jit
re-specialization.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.steps import make_decode_step, make_verify_step


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class CloudEngine:
    """Fixed-slot serving engine for one model."""

    def __init__(self, cfg, params, *, max_slots: int = 8, s_max: int = 2048,
                 window: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.s_max = s_max
        self.window = window
        self.cache = M.init_cache(cfg, max_slots, s_max)
        self._verify = jax.jit(make_verify_step(cfg, window=window))
        self._decode = jax.jit(make_decode_step(cfg, window=window))
        self.vocab = cfg.vocab

    def reset_slot(self, slot: int):
        """Invalidate a slot's cache: positions -> -1 (stale K/V at invalid
        positions is never attended to), SSM/conv states -> 0."""

        def tree_invalidate(c):
            if not isinstance(c, dict):
                return c
            out = {}
            for k, v in c.items():
                if isinstance(v, dict):
                    out[k] = tree_invalidate(v)
                elif k == "pos":                       # (..., B, S)
                    out[k] = v.at[..., slot, :].set(-1)
                elif k == "state":                     # (..., B, H, P, N)
                    out[k] = v.at[..., slot, :, :, :].set(0)
                elif k == "conv":                      # (..., B, W-1, C)
                    out[k] = v.at[..., slot, :, :].set(0)
                else:                                  # k/v buffers: stale ok
                    out[k] = v
            return out

        self.cache = tree_invalidate(self.cache)

    # ------------------------------------------------------------------
    def feed(self, tokens: np.ndarray, positions: np.ndarray) -> np.ndarray:
        """Chunked (partial) prefill over all slots.

        tokens, positions: (max_slots, C) int32; positions == -1 marks
        padding/idle.  Returns logits (max_slots, C, V) as numpy.
        """
        C = tokens.shape[1]
        Cb = _bucket(C)
        if Cb != C:
            pad = Cb - C
            tokens = np.pad(tokens, ((0, 0), (0, pad)))
            positions = np.pad(positions, ((0, 0), (0, pad)),
                               constant_values=-1)
        logits, self.cache = self._verify(
            self.params, self.cache,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32))
        return np.asarray(logits[:, :C], np.float32)

    def decode(self, tokens: np.ndarray, positions: np.ndarray) -> np.ndarray:
        """One decode step for all slots. tokens/positions: (max_slots, 1).

        Returns last-token logits (max_slots, V)."""
        logits, self.cache = self._decode(
            self.params, self.cache,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32))
        return np.asarray(logits, np.float32)
