"""Cloud execution engine: slot-based continuous batching on fixed-shape
jit-compiled steps (the TPU-idiomatic equivalent of vLLM's engine; see
DESIGN.md §2).

The engine is *mechanism only*: it owns the KV/SSM cache pytree and
exposes fixed-shape ``feed`` (chunked partial prefill over any slots),
``prefill`` and ``decode`` steps.  All batching *policy* lives in
``serving/scheduler.py`` (Algorithm 1 of the paper).

Device-residency contract (the serving hot path, docs/serving_api.md):

* Full-vocab logits NEVER leave the device on verify/decode iterations.
  The jitted steps carry a fused verification epilogue
  (models/steps.fused_verify_epilogue) that reduces each row to its
  argmax id, the gathered probability of the known next token, and a
  top-k compressed sampling support — ``feed`` returns (slots, chunk)
  ids plus (slots, chunk, K) sparse rows, ``decode`` returns (slots,)
  ids plus (slots, K) rows.
* ``prefill`` additionally fetches ONE full-vocab row per slot (the
  last prompt position, gathered on device), which seeds the sampling
  verifier's pre-draft row; this is per-prefill, not per-iteration.
* The cache pytree is donated to every step (``donate_argnums``), so
  feed/decode/verify update it in place on backends that support
  donation, and ``reset_slot`` is a single jitted slot-masked update
  (one dispatch) instead of a host tree walk.
* ``feed_logits`` / ``decode_logits`` are the legacy/debug path that
  does round-trip the full (slots, chunk, V) tensor — kept for
  before/after benchmarking (benchmarks/hotpath_bench.py) and the
  fused-vs-host-numpy identity tests.

Ragged per-slot chunks are padded to the iteration width; padded entries
carry position -1, which ``cache_write`` drops (never pollutes the
cache).  Chunk widths snap to a small fixed bucket ladder so jit
re-specialization is bounded by ``len(feed_buckets)`` (wider inputs are
fed through multiple max-bucket chunks); ``compile_stats`` reports the
specializations actually taken.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.steps import (make_cloud_decode_step, make_cloud_verify_step,
                                make_decode_step, make_verify_step)

DEFAULT_FEED_BUCKETS = (8, 16, 32, 64, 128, 256)


def _call_donated(fn, *args):
    """Invoke a donated jitted step.  CPU (and some other backends)
    silently ignore buffer donation; the per-compilation warning is not
    actionable here, and the suppression stays scoped to this call so
    the process-global warning state is untouched."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return fn(*args)


@dataclass(frozen=True)
class VerifyRows:
    """Fused verification state for one feed (host-resident).

    All arrays are indexed by the caller's ``sel_idx`` selection plane
    (R = verify_rows_max): entry r of slot b describes the chunk row
    ``sel_idx[b, r]``.

    token_id: (slots, R) int32  -- argmax over the vocab
    p_draft:  (slots, R) f32    -- softmax prob of the row's target token
    topk_idx: (slots, R, K) int32
    topk_val: (slots, R, K) f32 -- top-k sampling support of the row
    """
    token_id: np.ndarray
    p_draft: np.ndarray
    topk_idx: np.ndarray
    topk_val: np.ndarray

    @property
    def nbytes(self) -> int:
        return (self.token_id.nbytes + self.p_draft.nbytes
                + self.topk_idx.nbytes + self.topk_val.nbytes)


@dataclass(frozen=True)
class DecodeRows:
    """Fused per-slot decode result: argmax id + top-k sampling support."""
    token_id: np.ndarray          # (slots,) int32
    topk_idx: np.ndarray          # (slots, K) int32
    topk_val: np.ndarray          # (slots, K) f32

    @property
    def nbytes(self) -> int:
        return (self.token_id.nbytes + self.topk_idx.nbytes
                + self.topk_val.nbytes)


def _reset_cache_slot(cache, slot):
    """Slot-masked cache invalidation: positions -> -1 (stale K/V at
    invalid positions is never attended to), SSM/conv states -> 0.
    ``slot`` is a traced scalar, so one compiled program serves every
    slot."""

    def walk(c):
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k == "pos":                       # (..., B, S)
                out[k] = v.at[..., slot, :].set(-1)
            elif k == "state":                     # (..., B, H, P, N)
                out[k] = v.at[..., slot, :, :, :].set(0)
            elif k == "conv":                      # (..., B, W-1, C)
                out[k] = v.at[..., slot, :, :].set(0)
            else:                                  # k/v buffers: stale ok
                out[k] = v
        return out

    return walk(cache)


class CloudEngine:
    """Fixed-slot serving engine for one model."""

    def __init__(self, cfg, params, *, max_slots: int = 8, s_max: int = 2048,
                 window: int = 0, verify_top_k: int = 8,
                 verify_rows_max: int = 8,
                 feed_buckets: tuple = DEFAULT_FEED_BUCKETS):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.s_max = s_max
        self.window = window
        self.vocab = cfg.vocab
        self.verify_top_k = max(1, min(verify_top_k, cfg.vocab))
        # vocab-sized epilogue reductions run on at most this many
        # selected rows per slot per iteration (>= gamma + 1)
        self.verify_rows_max = verify_rows_max
        self.feed_buckets = tuple(sorted(feed_buckets))
        self.cache = M.init_cache(cfg, max_slots, s_max)
        self._step = jax.jit(
            make_cloud_verify_step(cfg, window=window,
                                   top_k=self.verify_top_k),
            donate_argnums=1)
        # greedy-only iterations skip the probability epilogue entirely
        self._step_greedy = jax.jit(
            make_cloud_verify_step(cfg, window=window,
                                   top_k=self.verify_top_k,
                                   with_dists=False),
            donate_argnums=1)
        self._decode = jax.jit(
            make_cloud_decode_step(cfg, window=window,
                                   top_k=self.verify_top_k),
            donate_argnums=1)
        # legacy/debug full-logits path (bench + identity tests)
        self._raw_verify = jax.jit(make_verify_step(cfg, window=window),
                                   donate_argnums=1)
        self._raw_decode = jax.jit(make_decode_step(cfg, window=window),
                                   donate_argnums=1)
        self._reset = jax.jit(_reset_cache_slot, donate_argnums=0)
        # telemetry: host transfer + jit specialization accounting
        self.bytes_to_host = 0
        self._calls = {"feed": 0, "prefill": 0, "decode": 0,
                       "feed_logits": 0, "decode_logits": 0}
        self._specializations: set = set()

    # -- telemetry ------------------------------------------------------
    @property
    def compile_stats(self) -> dict:
        """Which (step, bucket) jit specializations this engine took, and
        how often each entry point ran — the bench asserts the bucket
        ladder bounds re-specialization."""
        return dict(
            calls=dict(self._calls),
            buckets=sorted({b for kind, b in self._specializations
                            if kind in ("fused", "fused_greedy")}),
            specializations=sorted(self._specializations),
            n_specializations=len(self._specializations),
            bytes_to_host=self.bytes_to_host,
        )

    # -- cache management ----------------------------------------------
    def reset_slot(self, slot: int):
        """Invalidate a slot's cache in one jitted, donated dispatch."""
        self.cache = _call_donated(self._reset, self.cache, jnp.int32(slot))

    # -- bucketing ------------------------------------------------------
    def _bucket_of(self, n: int) -> int:
        for b in self.feed_buckets:
            if n <= b:
                return b
        return self.feed_buckets[-1]

    def _chunks(self, C: int):
        """Split a width-C feed into ladder-bounded sub-chunks."""
        cap = self.feed_buckets[-1]
        off = 0
        while off < C:
            yield off, min(cap, C - off)
            off += cap

    @staticmethod
    def _pad(arr, width, fill):
        pad = width - arr.shape[1]
        if pad <= 0:
            return arr
        return np.pad(arr, ((0, 0), (0, pad)), constant_values=fill)

    def _run_fused(self, tokens, positions, targets, sel_idx, last_local,
                   with_dists=True):
        """One fused sub-chunk; returns lazy (device) outputs.  Callers
        convert only what they need."""
        C = tokens.shape[1]
        Cb = self._bucket_of(C)
        self._specializations.add(
            ("fused" if with_dists else "fused_greedy", Cb))
        step = self._step if with_dists else self._step_greedy
        out, self.cache = _call_donated(
            step, self.params, self.cache,
            jnp.asarray(self._pad(tokens, Cb, 0), jnp.int32),
            jnp.asarray(self._pad(positions, Cb, -1), jnp.int32),
            jnp.asarray(self._pad(targets, Cb, -1), jnp.int32),
            jnp.asarray(sel_idx, jnp.int32),
            jnp.asarray(last_local, jnp.int32))
        return out

    # ------------------------------------------------------------------
    def feed(self, tokens: np.ndarray, positions: np.ndarray,
             targets: np.ndarray | None = None,
             sel_idx: np.ndarray | None = None,
             need_dists: bool = True) -> VerifyRows:
        """Chunked (partial) prefill over all slots, fused epilogue.

        tokens, positions: (max_slots, C) int32; positions == -1 marks
        padding/idle.  ``targets`` (max_slots, C) carries, per row, the
        token id whose probability the verifier will test (-1 = none);
        ``sel_idx`` (max_slots, R) the local indices of the rows whose
        p/top-k state the verifier will consume.  ``need_dists=False``
        (iterations whose batched requests are all greedy) selects the
        argmax-only step variant.  Only the fused rows cross to the host.
        """
        self._calls["feed"] += 1
        B, C = tokens.shape
        R = self.verify_rows_max
        if targets is None:
            targets = np.full((B, C), -1, np.int32)
        if sel_idx is None:
            sel_idx = np.full((B, R), -1, np.int32)
        zeros = np.zeros(B, np.int32)
        tok_acc = np.zeros((B, R), np.int32)
        p_acc = np.zeros((B, R), np.float32)
        ki_acc = np.zeros((B, R, self.verify_top_k), np.int32)
        kv_acc = np.zeros((B, R, self.verify_top_k), np.float32)
        moved_bytes = 0
        for off, w in self._chunks(C):
            sl = slice(off, off + w)
            in_chunk = (sel_idx >= off) & (sel_idx < off + w)
            sub_sel = np.where(in_chunk, sel_idx - off, -1).astype(np.int32)
            res = self._run_fused(tokens[:, sl], positions[:, sl],
                                  targets[:, sl], sub_sel, zeros,
                                  with_dists=need_dists)
            if in_chunk.any():      # only selected rows cross to the host
                tok = np.asarray(res[0], np.int32)
                tok_acc = np.where(in_chunk, tok, tok_acc)
                moved_bytes += tok.nbytes
                if need_dists:
                    p_acc = np.where(in_chunk, np.asarray(res[1], np.float32),
                                     p_acc)
                    ki_acc = np.where(in_chunk[..., None],
                                      np.asarray(res[2], np.int32), ki_acc)
                    kv_acc = np.where(in_chunk[..., None],
                                      np.asarray(res[3], np.float32), kv_acc)
                    moved_bytes += (p_acc.nbytes + ki_acc.nbytes
                                    + kv_acc.nbytes)
        self.bytes_to_host += moved_bytes
        return VerifyRows(token_id=tok_acc, p_draft=p_acc,
                          topk_idx=ki_acc, topk_val=kv_acc)

    def prefill(self, tokens: np.ndarray, positions: np.ndarray) -> np.ndarray:
        """Prompt prefill over all slots.  Returns each slot's LAST valid
        row's full logits (max_slots, V) — gathered on device, one
        vocab-row per slot — and writes the cache.  Slots with no valid
        positions return zeros."""
        self._calls["prefill"] += 1
        B, C = tokens.shape
        counts = (positions >= 0).sum(axis=1)
        targets = np.full((B, C), -1, np.int32)
        no_sel = np.full((B, self.verify_rows_max), -1, np.int32)
        out = np.zeros((B, self.vocab), np.float32)
        for off, w in self._chunks(C):
            sl = slice(off, off + w)
            local = np.clip(counts - 1 - off, 0, w - 1).astype(np.int32)
            # only the last-row gather is consumed: the argmax-only step
            # variant suffices (no extra specialization, no wasted top-k)
            res = self._run_fused(tokens[:, sl], positions[:, sl],
                                  targets[:, sl], no_sel, local,
                                  with_dists=False)
            sel = (counts > 0) & (counts - 1 >= off) & (counts - 1 < off + w)
            if sel.any():
                last = np.asarray(res[4], np.float32)
                out[sel] = last[sel]
                self.bytes_to_host += last.nbytes
        return out

    def decode(self, tokens: np.ndarray, positions: np.ndarray) -> DecodeRows:
        """One decode step for all slots. tokens/positions: (max_slots, 1).

        Returns fused last-token rows (argmax + top-k support)."""
        self._calls["decode"] += 1
        self._specializations.add(("decode", 1))
        (tok, tk_i, tk_v), self.cache = _call_donated(
            self._decode, self.params, self.cache,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32))
        rows = DecodeRows(token_id=np.asarray(tok, np.int32),
                          topk_idx=np.asarray(tk_i, np.int32),
                          topk_val=np.asarray(tk_v, np.float32))
        self.bytes_to_host += rows.nbytes
        return rows

    # -- legacy/debug full-logits path ---------------------------------
    def feed_logits(self, tokens: np.ndarray,
                    positions: np.ndarray) -> np.ndarray:
        """Pre-fusion semantics: round-trip the full (max_slots, C, V)
        logits as host float32.  Bench baseline + identity tests."""
        self._calls["feed_logits"] += 1
        parts = []
        for off, w in self._chunks(tokens.shape[1]):
            sl = slice(off, off + w)
            Cb = self._bucket_of(w)
            self._specializations.add(("raw", Cb))
            logits, self.cache = _call_donated(
                self._raw_verify, self.params, self.cache,
                jnp.asarray(self._pad(tokens[:, sl], Cb, 0), jnp.int32),
                jnp.asarray(self._pad(positions[:, sl], Cb, -1), jnp.int32))
            parts.append(np.asarray(logits[:, :w], np.float32))
        out = np.concatenate(parts, axis=1)
        self.bytes_to_host += out.nbytes
        return out

    def decode_logits(self, tokens: np.ndarray,
                      positions: np.ndarray) -> np.ndarray:
        """Pre-fusion decode: full last-token logits (max_slots, V)."""
        self._calls["decode_logits"] += 1
        self._specializations.add(("raw_decode", 1))
        logits, self.cache = _call_donated(
            self._raw_decode, self.params, self.cache,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32))
        out = np.asarray(logits, np.float32)
        self.bytes_to_host += out.nbytes
        return out
