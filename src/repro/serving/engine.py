"""Cloud execution engine: slot-based continuous batching on fixed-shape
jit-compiled steps (the TPU-idiomatic equivalent of vLLM's engine; see
DESIGN.md §2).

The engine is *mechanism only*: it owns the KV/SSM cache pytree and
exposes fixed-shape ``feed`` (chunked partial prefill over any slots),
``prefill`` and ``decode`` steps.  All batching *policy* lives in
``serving/scheduler.py`` (Algorithm 1 of the paper).

Device-residency contract (the serving hot path, docs/serving_api.md):

* Full-vocab logits NEVER leave the device on verify/decode iterations.
  The jitted steps carry a fused verification epilogue
  (models/steps.fused_verify_epilogue) that reduces each row to its
  argmax id, the gathered probability of the known next token, and a
  top-k compressed sampling support — ``feed`` returns (slots, chunk)
  ids plus (slots, chunk, K) sparse rows, ``decode`` returns (slots,)
  ids plus (slots, K) rows.
* ``prefill`` additionally fetches ONE full-vocab row per slot (the
  last prompt position, gathered on device), which seeds the sampling
  verifier's pre-draft row; this is per-prefill, not per-iteration.
* The cache pytree is donated to every step (``donate_argnums``), so
  feed/decode/verify update it in place on backends that support
  donation, and ``reset_slot`` is a single jitted slot-masked update
  (one dispatch) instead of a host tree walk.
* ``feed_logits`` / ``decode_logits`` are the legacy/debug path that
  does round-trip the full (slots, chunk, V) tensor — kept for
  before/after benchmarking (benchmarks/hotpath_bench.py) and the
  fused-vs-host-numpy identity tests.

Ragged per-slot chunks are padded to the iteration width; padded entries
carry position -1, which ``cache_write`` drops (never pollutes the
cache).  Chunk widths snap to a small fixed bucket ladder so jit
re-specialization is bounded by ``len(feed_buckets)`` (wider inputs are
fed through multiple max-bucket chunks); ``compile_stats`` reports the
specializations actually taken.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.steps import (make_cloud_decode_step, make_cloud_verify_step,
                                make_decode_step, make_verify_step)
from repro.serving.trace import NULL_TRACER

DEFAULT_FEED_BUCKETS = (8, 16, 32, 64, 128, 256)


class BlockPoolExhausted(RuntimeError):
    """Raised when a step needs more KV blocks than the pool has free.
    The scheduler's admission/preemption layer is supposed to prevent
    this from ever reaching the engine; seeing it means a policy bug or
    an unguarded driver (e.g. plain decode on an undersized pool)."""


_CHAIN_ROOT = 0x53594E45  # prefix-hash chain seed ("SYNE")


class BlockAllocator:
    """Host-side free-list allocator over the paged KV block pool.

    Mechanism only: tracks which pool blocks back which slot and keeps
    the (max_slots, max_bps) block table mirror the engine pushes to the
    device cache.  Admission/eviction *policy* lives in the scheduler.
    Blocks are recycled FIFO so reuse spreads across the pool.

    With ``share_prefix=True`` blocks are ref-counted and a prefix index
    maps chain hashes of *full* leading token blocks to the pool block
    that already holds their K/V.  A new prompt's leading blocks are
    matched against the index and mapped into its table (ref++) instead
    of allocated; a write into a block with refcount > 1 forks a private
    copy first (copy-on-write — ``prepare_writes`` does the
    bookkeeping, the engine clones pool content).  A block returns to
    the free list only when its refcount reaches zero, at which point it
    also leaves the index.

    With ``retain_prefix=True`` (implies sharing) a fully-written,
    registered prefix block whose refcount hits zero does NOT free:
    it parks on the cached-free LRU (``_cached``, insertion-ordered —
    oldest first) and stays in the index with its pool content intact,
    so a recurring prompt hits across *non-overlapping* sessions.
    Allocation prefers the truly-free list and reclaims LRU cached
    blocks only under pressure (``_take_block``: unregister + queue for
    invalidation — the engine flushes ``take_reclaimed`` before the
    next write).  ``retain_blocks`` caps the LRU (0 = unbounded).

    Index entries are exact, not trust-the-hash: each registered block
    stores ``(prev_chain_hash, its token tuple)`` and a match verifies
    both, so a chain-hash collision can only *miss* a share, never map
    wrong content.
    """

    def __init__(self, n_blocks: int, block_size: int, max_slots: int,
                 max_blocks_per_slot: int, share_prefix: bool = False,
                 retain_prefix: bool = False, retain_blocks: int = 0):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_blocks_per_slot = max_blocks_per_slot
        self.share_prefix = share_prefix or retain_prefix
        self.retain_prefix = retain_prefix
        self.retain_blocks = int(retain_blocks)
        self._free: deque[int] = deque(range(n_blocks))
        self.table = np.full((max_slots, max_blocks_per_slot), -1, np.int32)
        self.n_blocks_of = np.zeros(max_slots, np.int64)
        self.peak_used = 0
        # per-block reference counts (always maintained; every count is 1
        # until adopt_prefix creates the first share)
        self.ref = np.zeros(n_blocks, np.int64)
        # prefix index: chain hash -> block id, plus the reverse map and
        # the exact (prev_hash, tokens) contents for verification
        self._index: dict[int, int] = {}
        self._rindex: dict[int, int] = {}
        self._contents: dict[int, tuple] = {}
        # canonical-chain shadows: content-duplicate blocks (e.g. the
        # unmatched last full block of an identical prompt) registered
        # under the chain hash an earlier block already owns; when the
        # primary dies, a live shadow is promoted so the share survives
        self._shadow: dict[int, list[int]] = {}
        # blocks registered whose content the imminent prompt feed will
        # write: that first write realizes the registered content and
        # must neither fork nor unregister
        self._fill: set[int] = set()
        # cached-free LRU (retain_prefix): registered blocks at ref 0
        # whose content stays valid in the pool.  Insertion-ordered dict
        # used as an ordered set — first key is the LRU victim.
        self._cached: dict[int, None] = {}
        # reclaimed cached blocks whose stale pool positions the engine
        # must invalidate before the next write (see take_reclaimed)
        self._reclaim_pending: list[int] = []
        # telemetry
        # tracing handle (serving/trace.py): installed by the scheduler
        # when tracing is on; the NULL_TRACER default keeps every
        # ``if self.tracer.enabled`` guard below allocation-free
        self.tracer = NULL_TRACER
        self.trace_replica = 0
        self.dedupe_hit_blocks = 0   # cumulative blocks adopted via index
        self.cow_copies = 0          # cumulative copy-on-write forks
        self.shadow_promotions = 0   # duplicates promoted to primary
        self.revived_blocks = 0      # cached-free blocks re-adopted live
        self.reclaimed_blocks = 0    # cached-free blocks reclaimed (LRU)
        self.tail_shared_tokens = 0  # partial-block tail rows copied

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Cached-free blocks: refcount 0 but still registered (their
        pool content is valid and adoptable until reclaimed)."""
        return len(self._cached)

    @property
    def used_blocks(self) -> int:
        """Blocks live in some slot's table (cached-free blocks are not
        used — they are reclaimable supply)."""
        return self.n_blocks - len(self._free) - len(self._cached)

    @property
    def shared_blocks(self) -> int:
        """Blocks currently mapped by more than one slot."""
        return int((self.ref >= 2).sum())

    @property
    def s_max(self) -> int:
        return self.block_size * self.max_blocks_per_slot

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to back a sequence of ``n_tokens`` (the caller
        caps at s_max tokens — the circular window wraps beyond it)."""
        need = -(-max(int(n_tokens), 0) // self.block_size)
        return min(need, self.max_blocks_per_slot)

    def needed(self, slot: int, seq_len: int) -> int:
        """Additional blocks ``slot`` needs to cover ``seq_len`` tokens."""
        return max(0, self.blocks_for(seq_len) - int(self.n_blocks_of[slot]))

    def allocatable_blocks(self, reserved=()) -> int:
        """Blocks an allocation can draw on: the truly-free list plus
        cached-free (LRU-reclaimable) blocks, minus any cached blocks
        the caller is about to adopt (``reserved`` — an adopted cached
        block is revived, not reclaimed, so it cannot double as
        supply)."""
        held = sum(1 for b in reserved if b in self._cached)
        return len(self._free) + len(self._cached) - held

    def _take_block(self):
        """Pop a writable block: truly-free first, else reclaim the
        LRU cached-free block (unregister + queue its stale positions
        for invalidation).  Returns None when both tiers are dry."""
        if self._free:
            return self._free.popleft()
        if self._cached:
            b = next(iter(self._cached))
            del self._cached[b]
            self._unregister(b)
            self._reclaim_pending.append(b)
            self.reclaimed_blocks += 1
            return b
        return None

    def take_reclaimed(self) -> list[int]:
        """Drain the ids of blocks reclaimed from the cached-free LRU
        since the last drain.  The engine MUST invalidate their pool
        positions before the next cache write dispatch — their content
        was valid (that is the point of retention) and would otherwise
        read as live rows through the new owner's table."""
        out, self._reclaim_pending = self._reclaim_pending, []
        return out

    def map_block(self, slot: int, bid: int) -> None:
        """Append an existing block to ``slot``'s table (ref++),
        reviving it from the cached-free LRU if parked there."""
        if bid in self._cached:
            del self._cached[bid]
            self.revived_blocks += 1
            if self.tracer.enabled:
                self.tracer.instant("prefix_revive",
                                    replica=self.trace_replica, slot=slot)
        j = int(self.n_blocks_of[slot])
        self.table[slot, j] = bid
        self.ref[bid] += 1
        self.n_blocks_of[slot] = j + 1
        self.peak_used = max(self.peak_used, self.used_blocks)

    def append_fresh(self, slot: int):
        """Allocate one writable block and append it to ``slot``'s
        table (ref=1).  Returns the block id, or None if the pool (both
        tiers) is dry."""
        b = self._take_block()
        if b is None:
            return None
        j = int(self.n_blocks_of[slot])
        self.table[slot, j] = b
        self.ref[b] = 1
        self.n_blocks_of[slot] = j + 1
        self.peak_used = max(self.peak_used, self.used_blocks)
        return b

    def extend(self, slot: int, seq_len: int) -> bool:
        """Grow ``slot`` to cover ``seq_len`` tokens.  All-or-nothing:
        returns False (no allocation) if the pool cannot supply it."""
        need = self.needed(slot, seq_len)
        if need > self.allocatable_blocks():
            return False
        for _ in range(need):
            self.append_fresh(slot)
        return True

    def release(self, slot: int) -> np.ndarray:
        """Drop ``slot``'s reference on all its blocks.  Blocks whose
        refcount hits zero return to the pool (and leave the prefix
        index) — except, under ``retain_prefix``, fully-realized
        registered blocks, which park on the cached-free LRU with index
        entry and pool content intact.  Blocks still mapped by a
        sibling stay live and MUST NOT be invalidated.  Returns the
        truly freed block ids (the engine invalidates their pool
        positions); cached blocks are deliberately NOT in that list."""
        n = int(self.n_blocks_of[slot])
        freed = []
        for j in range(n):
            b = int(self.table[slot, j])
            self.ref[b] -= 1
            if self.ref[b] == 0:
                if (self.retain_prefix and b in self._rindex
                        and b not in self._fill):
                    # cross-session retention: keep the chain entry and
                    # the pool bytes; MRU position in the LRU order
                    self._cached[b] = None
                else:
                    self._free.append(b)
                    self._unregister(b)
                    freed.append(b)
        self.table[slot, :] = -1
        self.n_blocks_of[slot] = 0
        # enforce the retention cap, oldest first
        cap = self.retain_blocks
        while cap and len(self._cached) > cap:
            b = next(iter(self._cached))
            del self._cached[b]
            self._unregister(b)
            self._free.append(b)
            freed.append(b)
        return np.asarray(freed, np.int32)

    # -- prefix sharing / copy-on-write --------------------------------
    def _chain(self, tokens, n_full: int):
        """Yield (chain_hash, prev_hash, block_tuple) for the first
        ``n_full`` full token blocks."""
        h = _CHAIN_ROOT
        bs = self.block_size
        for j in range(n_full):
            blk = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            prev, h = h, hash((h, blk))
            yield h, prev, blk

    def match_prefix(self, tokens) -> list[int]:
        """Read-only probe: pool block ids already holding the leading
        full blocks of ``tokens``, in chain order, stopping at the first
        divergence.  Capped at ``len(tokens) - 1`` tokens so a fully
        cached prompt still feeds its last token (the prefill's
        full-vocab seed row must be computed).  Prompts longer than
        s_max wrap over their own leading blocks and never share."""
        if not self.share_prefix or len(tokens) > self.s_max:
            return []
        n_full = min((len(tokens) - 1) // self.block_size,
                     self.max_blocks_per_slot)
        out = []
        for h, prev, blk in self._chain(tokens, n_full):
            bid = self._index.get(h)
            if bid is None or self._contents.get(bid) != (prev, blk):
                break
            out.append(bid)
        return out

    def adopt_prefix(self, slot: int, bids: list[int]) -> None:
        """Map matched prefix blocks into an empty slot's table (ref++):
        the dedupe hit — no allocation, no feed, just an indirection.
        Cached-free blocks in ``bids`` are revived (the cross-session
        hit: the prior owner is long gone, the bytes are still here)."""
        assert int(self.n_blocks_of[slot]) == 0, \
            "prefix adoption requires a freshly admitted (empty) slot"
        for b in bids:
            self.map_block(slot, b)
        self.dedupe_hit_blocks += len(bids)
        if bids and self.tracer.enabled:
            self.tracer.instant("prefix_adopt",
                                replica=self.trace_replica, slot=slot,
                                n=len(bids))

    def chain_of(self, bid: int):
        """Registration record of a block: ``(chain_hash, prev_hash,
        token_tuple)``, or None if unregistered."""
        h = self._rindex.get(bid)
        if h is None:
            return None
        prev, blk = self._contents[bid]
        return h, prev, blk

    def register_block(self, bid: int, h: int, prev: int, blk: tuple,
                       fill: bool = False) -> None:
        """Publish one block under chain hash ``h`` with exact contents
        ``(prev, blk)``.  ``fill=False`` registers it *realized* (its
        pool content already holds the promised rows — e.g. scattered
        from the host store), so a later sole-owned divergent write
        correctly unregisters instead of skipping the fork."""
        if bid in self._rindex:
            return
        self._rindex[bid] = h
        self._contents[bid] = (prev, blk)
        if fill:
            self._fill.add(bid)
        if h not in self._index:
            self._index[h] = bid
        else:
            self._shadow.setdefault(h, []).append(bid)

    def match_tail(self, tokens, n_matched: int):
        """Partial-block tail probe: after ``n_matched`` fully matched
        blocks, find a registered block whose content extends the same
        chain and shares the longest row prefix with the next (partial)
        block of ``tokens``.  Returns ``(bid, rows)`` with rows >= 1, or
        None.  Capped at ``len(tokens) - 1`` total so the prefill still
        feeds at least the last token; fill-pending candidates are
        excluded (their pool rows are not written yet, so there is
        nothing to copy)."""
        if not self.share_prefix or len(tokens) > self.s_max:
            return None
        bs = self.block_size
        lo = n_matched * bs
        cap = min(len(tokens) - 1 - lo, bs)
        if cap <= 0 or n_matched >= self.max_blocks_per_slot:
            return None
        h = _CHAIN_ROOT
        for ch, _prev, _blk in self._chain(tokens, n_matched):
            h = ch
        want = tuple(int(t) for t in tokens[lo:lo + cap])
        best = None
        for bid, (prev, blk) in self._contents.items():
            if prev != h or bid in self._fill:
                continue
            r = 0
            while r < cap and blk[r] == want[r]:
                r += 1
            if r > 0 and (best is None or r > best[1]):
                best = (bid, r)
                if r == cap:
                    break
        return best

    def register_prefix(self, slot: int, tokens) -> None:
        """Publish ``slot``'s full prompt blocks in the prefix index.
        Called at admission, *before* the prompt feed writes them: the
        blocks are marked fill-pending so the realizing write neither
        forks nor unregisters them, and streams admitted into the same
        batch can already adopt them (the batched step scatters K/V
        before any suffix row attends)."""
        if not self.share_prefix or len(tokens) > self.s_max:
            return
        n_full = min(len(tokens) // self.block_size,
                     self.max_blocks_per_slot)
        for j, (h, prev, blk) in enumerate(self._chain(tokens, n_full)):
            bid = int(self.table[slot, j])
            if bid < 0 or bid in self._rindex:
                continue                 # adopted / already registered
            # canonical-chain registration: when the chain hash already
            # has a primary (e.g. this prompt's last full block sat
            # past the len-1 match cap, so a content duplicate was
            # allocated), register_block records the duplicate under the
            # SAME canonical hash so _unregister can promote it when the
            # primary dies — without it, a content-identical prefix
            # would miss a share that still physically exists.
            self.register_block(bid, h, prev, blk, fill=True)

    def _unregister(self, bid: int) -> None:
        h = self._rindex.pop(bid, None)
        if h is not None:
            self._contents.pop(bid, None)
            shadows = self._shadow.get(h)
            if self._index.get(h) == bid:
                self._index.pop(h, None)
                if shadows:
                    # promote a live content duplicate: the share
                    # survives the primary block's death
                    self._index[h] = shadows.pop(0)
                    self.shadow_promotions += 1
            elif shadows and bid in shadows:
                shadows.remove(bid)
            if shadows is not None and not shadows:
                self._shadow.pop(h, None)
        self._fill.discard(bid)

    def cow_demand(self, slot: int, lo: int, hi: int) -> int:
        """Forks a write covering absolute positions [lo, hi) would
        need: mapped blocks with refcount > 1 (fill-pending blocks are
        about to be realized, not forked).  The scheduler reserves these
        on top of ``needed`` growth."""
        if not self.share_prefix or hi <= lo:
            return 0
        idxs = {(p % self.s_max) // self.block_size
                for p in range(int(lo), int(hi))}
        n = 0
        for i in idxs:
            bid = int(self.table[slot, i])
            if bid >= 0 and bid not in self._fill and self.ref[bid] > 1:
                n += 1
        return n

    def prepare_writes(self, slot: int, idxs) -> list[tuple[int, int]]:
        """Copy-on-write bookkeeping for an imminent write into
        ``slot``'s table entries ``idxs``.  Three cases per block:

        * fill-pending (just registered, this write realizes the
          promised content): cleared, nothing else happens;
        * refcount > 1: the writer is re-pointed at a fresh block and a
          ``(src, dst)`` fork pair is returned — the engine must clone
          pool content src -> dst *before* the write executes;
        * sole-owned but registered: the content is about to diverge
          from the published hash, so the block leaves the index.
        """
        pairs = []
        for i in idxs:
            i = int(i)
            bid = int(self.table[slot, i])
            if bid < 0:
                continue
            if bid in self._fill:
                self._fill.discard(bid)
                continue
            if self.ref[bid] > 1:
                dst = self._take_block()
                if dst is None:
                    raise BlockPoolExhausted(
                        f"slot {slot} must copy-on-write fork shared "
                        f"block {bid} but the pool is dry")
                self.ref[bid] -= 1
                self.ref[dst] = 1
                self.table[slot, i] = dst
                self.cow_copies += 1
                if self.tracer.enabled:
                    self.tracer.instant("cow_fork",
                                        replica=self.trace_replica,
                                        slot=slot)
                pairs.append((bid, dst))
            elif bid in self._rindex:
                self._unregister(bid)
        self.peak_used = max(self.peak_used, self.used_blocks)
        return pairs


def _reset_paged_blocks(cache, blocks):
    """Invalidate the pool positions of freed blocks (one jitted,
    donated dispatch).  ``blocks`` is a fixed-size (max_bps,) int32 array
    padded with -1; padding maps out of bounds, which scatter drops.
    Freed K/V stays stale — a block is only ever read through a table
    entry, and re-allocated blocks are re-written before their positions
    turn valid again."""

    def walk(c):
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k == "pos":                       # (n, nb, bs)
                idx = jnp.where(blocks >= 0, blocks, v.shape[1])
                out[k] = v.at[:, idx].set(-1)
            else:
                out[k] = v
        return out

    return walk(cache)


def _set_block_tables(cache, table):
    """Replace every ``block_tables`` leaf with the allocator's current
    (max_slots, max_bps) table, broadcast along the layer axis."""

    def walk(c):
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k == "block_tables":
                out[k] = jnp.broadcast_to(table[None], v.shape)
            else:
                out[k] = v
        return out

    return walk(cache)


def _call_donated(fn, *args):
    """Invoke a donated jitted step.  CPU (and some other backends)
    silently ignore buffer donation; the per-compilation warning is not
    actionable here, and the suppression stays scoped to this call so
    the process-global warning state is untouched."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return fn(*args)


@dataclass(frozen=True)
class VerifyRows:
    """Fused verification state for one feed (host-resident).

    All arrays are indexed by the caller's ``sel_idx`` selection plane
    (R = verify_rows_max): entry r of slot b describes the chunk row
    ``sel_idx[b, r]``.

    token_id: (slots, R) int32  -- argmax over the vocab
    p_draft:  (slots, R) f32    -- softmax prob of the row's target token
    topk_idx: (slots, R, K) int32
    topk_val: (slots, R, K) f32 -- top-k sampling support of the row
    """
    token_id: np.ndarray
    p_draft: np.ndarray
    topk_idx: np.ndarray
    topk_val: np.ndarray

    @property
    def nbytes(self) -> int:
        return (self.token_id.nbytes + self.p_draft.nbytes
                + self.topk_idx.nbytes + self.topk_val.nbytes)


@dataclass(frozen=True)
class DecodeRows:
    """Fused per-slot decode result: argmax id + top-k sampling support."""
    token_id: np.ndarray          # (slots,) int32
    topk_idx: np.ndarray          # (slots, K) int32
    topk_val: np.ndarray          # (slots, K) f32

    @property
    def nbytes(self) -> int:
        return (self.token_id.nbytes + self.topk_idx.nbytes
                + self.topk_val.nbytes)


def _reset_cache_slot(cache, slot):
    """Slot-masked cache invalidation: positions -> -1 (stale K/V at
    invalid positions is never attended to), SSM/conv states -> 0.
    ``slot`` is a traced scalar, so one compiled program serves every
    slot."""

    def walk(c):
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k == "pos":                       # (..., B, S)
                out[k] = v.at[..., slot, :].set(-1)
            elif k == "state":                     # (..., B, H, P, N)
                out[k] = v.at[..., slot, :, :, :].set(0)
            elif k == "conv":                      # (..., B, W-1, C)
                out[k] = v.at[..., slot, :, :].set(0)
            else:                                  # k/v buffers: stale ok
                out[k] = v
        return out

    return walk(cache)


class CloudEngine:
    """Fixed-slot serving engine for one model."""

    def __init__(self, cfg, params, *, max_slots: int = 8, s_max: int = 2048,
                 window: int = 0, verify_top_k: int = 8,
                 verify_rows_max: int = 8,
                 feed_buckets: tuple = DEFAULT_FEED_BUCKETS,
                 cache_impl: str | None = None, block_size: int | None = None,
                 pool_blocks: int | None = None,
                 share_prefix: bool | None = None,
                 retain_prefix: bool | None = None,
                 retain_blocks: int | None = None,
                 host_dedupe: bool | None = None,
                 swap: bool | None = None,
                 host_swap_blocks: int | None = None,
                 paged_block_kv: int | None = None,
                 kv_splits: int | None = None):
        # paged-kernel streaming knobs (fused-DMA width / flash-decode
        # split-KV) ride on the config so the jitted steps see them
        if paged_block_kv is not None:
            cfg = cfg.replace(paged_block_kv=paged_block_kv)
        if kv_splits is not None:
            cfg = cfg.replace(paged_kv_splits=kv_splits)
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.s_max = s_max
        self.window = window
        self.vocab = cfg.vocab
        self.verify_top_k = max(1, min(verify_top_k, cfg.vocab))
        # vocab-sized epilogue reductions run on at most this many
        # selected rows per slot per iteration (>= gamma + 1)
        self.verify_rows_max = verify_rows_max
        self.feed_buckets = tuple(sorted(feed_buckets))
        # -- cache substrate: dense (slots x s_max up front) or paged
        # (shared block pool + per-slot block tables, memory-bound) ------
        self.cache_impl = cache_impl or getattr(cfg, "cache_impl", "dense")
        self.block_size = block_size or getattr(cfg, "kv_block_size", 16)
        self.allocator: BlockAllocator | None = None
        self.share_prefix = False
        self.swap_manager = None
        want_swap = bool(swap if swap is not None
                         else getattr(cfg, "kv_swap", False))
        if want_swap and self.cache_impl != "paged":
            raise ValueError("swap=True requires cache_impl='paged' "
                             "(dense caches have no block pool to swap)")
        if self.cache_impl == "paged":
            max_bps = -(-s_max // self.block_size)
            nb = (pool_blocks if pool_blocks is not None
                  else max_slots * max_bps)
            retain = bool(retain_prefix if retain_prefix is not None
                          else getattr(cfg, "retain_prefix", False))
            self.share_prefix = bool(
                share_prefix if share_prefix is not None
                else getattr(cfg, "share_prefix", False)) or retain
            self.allocator = BlockAllocator(
                nb, self.block_size, max_slots, max_bps,
                share_prefix=self.share_prefix,
                retain_prefix=retain,
                retain_blocks=(retain_blocks if retain_blocks is not None
                               else getattr(cfg, "retain_blocks", 0)))
            self.cache = M.init_cache(cfg, max_slots, s_max,
                                      cache_impl="paged",
                                      block_size=self.block_size,
                                      pool_blocks=nb)
            self._reset_blocks = jax.jit(_reset_paged_blocks,
                                         donate_argnums=0)
            self._copy_blocks = jax.jit(M.copy_cache_blocks,
                                        donate_argnums=0)
            self._copy_rows = jax.jit(M.copy_cache_block_rows,
                                      donate_argnums=0)
            self._tables_dirty = False
            if want_swap:
                # deferred import: swap.py imports this module
                from repro.serving.swap import HostSwapManager
                hb = (host_swap_blocks if host_swap_blocks is not None
                      else getattr(cfg, "host_swap_blocks", 0))
                dedupe = bool(host_dedupe if host_dedupe is not None
                              else getattr(cfg, "host_dedupe", True))
                self.swap_manager = HostSwapManager(self,
                                                    max_host_blocks=hb,
                                                    host_dedupe=dedupe)
        else:
            self.cache = M.init_cache(cfg, max_slots, s_max)
        self._step = jax.jit(
            make_cloud_verify_step(cfg, window=window,
                                   top_k=self.verify_top_k),
            donate_argnums=1)
        # greedy-only iterations skip the probability epilogue entirely
        self._step_greedy = jax.jit(
            make_cloud_verify_step(cfg, window=window,
                                   top_k=self.verify_top_k,
                                   with_dists=False),
            donate_argnums=1)
        self._decode = jax.jit(
            make_cloud_decode_step(cfg, window=window,
                                   top_k=self.verify_top_k),
            donate_argnums=1)
        # legacy/debug full-logits path (bench + identity tests)
        self._raw_verify = jax.jit(make_verify_step(cfg, window=window),
                                   donate_argnums=1)
        self._raw_decode = jax.jit(make_decode_step(cfg, window=window),
                                   donate_argnums=1)
        self._reset = jax.jit(_reset_cache_slot, donate_argnums=0)
        # telemetry: host transfer + jit specialization accounting
        self.bytes_to_host = 0
        self._calls = {"feed": 0, "prefill": 0, "decode": 0,
                       "feed_logits": 0, "decode_logits": 0}
        self._specializations: set = set()
        # fault injection (serving/router.py): a replica marked dead must
        # never serve again — any further compute dispatch raises
        self.dead = False

    def mark_dead(self):
        """Poison this engine: every subsequent compute dispatch raises.

        The router uses this when it kills a replica — its sessions are
        re-placed on survivors as from-scratch prefills, and nothing
        (not even slot release) may touch the dead replica's pool again,
        so a routing bug that still dispatches here fails loudly instead
        of silently corrupting the fault-injection tests."""
        self.dead = True

    # -- telemetry ------------------------------------------------------
    @property
    def compile_stats(self) -> dict:
        """Which (step, bucket) jit specializations this engine took, and
        how often each entry point ran — the bench asserts the bucket
        ladder bounds re-specialization."""
        return dict(
            calls=dict(self._calls),
            buckets=sorted({b for kind, b in self._specializations
                            if kind in ("fused", "fused_greedy")}),
            specializations=sorted(self._specializations),
            n_specializations=len(self._specializations),
            bytes_to_host=self.bytes_to_host,
        )

    # -- cache management ----------------------------------------------
    def reset_slot(self, slot: int):
        """Invalidate a slot's cache in one jitted, donated dispatch.
        Paged: the slot's blocks return to the pool and their pool
        positions are invalidated (a freed block must never read as
        valid through a future owner's table)."""
        if self.allocator is not None:
            if (self.swap_manager is not None
                    and not self.allocator.retain_prefix):
                # content-addressed demotion: without device retention,
                # the last sharer's exit would lose a recurring prefix;
                # park its sole-owned registered blocks in the host
                # store so a future session can adopt them (H2D scatter
                # instead of re-prefill)
                self.swap_manager.demote_slot(slot)
            freed = self.allocator.release(slot)
            self._invalidate_blocks(freed)
            self._tables_dirty = True
            self._sync_tables()
            return
        self.cache = _call_donated(self._reset, self.cache, jnp.int32(slot))

    # -- paged block management ----------------------------------------
    def _invalidate_blocks(self, bids):
        """Invalidate pool positions of ``bids`` in fixed-size chunked,
        jitted, donated dispatches (a freed or reclaimed block must
        never read as valid through a future owner's table)."""
        bids = list(bids)
        if not bids:
            return
        W = self.allocator.max_blocks_per_slot
        for off in range(0, len(bids), W):
            grp = bids[off:off + W]
            pad = np.full(W, -1, np.int32)
            pad[:len(grp)] = grp
            self.cache = _call_donated(self._reset_blocks, self.cache,
                                       jnp.asarray(pad))

    def _flush_reclaims(self):
        """Invalidate positions of blocks reclaimed from the cached-free
        LRU since the last flush.  A reclaimed block's content was fully
        valid (that is what retention preserves), so unlike the ordinary
        free path its stale rows WOULD read as live through the new
        owner's table; this must run before any dispatch that writes or
        reads the reclaimed blocks — and before ``_apply_forks``
        (wipe-then-copy keeps a fork destination's content; the reverse
        order would destroy it)."""
        if self.allocator is not None:
            self._invalidate_blocks(self.allocator.take_reclaimed())

    def _sync_tables(self):
        """Push the allocator's block-table mirror into every
        ``block_tables`` cache leaf (host-side leaf swap, no jit)."""
        if self.allocator is not None and self._tables_dirty:
            self.cache = _set_block_tables(
                self.cache, jnp.asarray(self.allocator.table))
            self._tables_dirty = False

    def _ensure_blocks(self, positions: np.ndarray):
        """Grow each active slot's allocation to cover the highest
        position this step writes (capped at s_max — the circular window
        wraps beyond it), forking any shared block the step would write
        into (copy-on-write) so siblings keep reading the original.
        Raises :class:`BlockPoolExhausted` when the pool is dry; the
        scheduler's admission + preemption layer is responsible for
        never letting that happen."""
        if self.dead:
            raise RuntimeError(
                "CloudEngine is marked dead (replica killed); no dispatch "
                "may reach it — sessions must be re-placed on a survivor")
        if self.allocator is None:
            return
        pos = np.asarray(positions)
        forks: list[tuple[int, int]] = []
        for slot in range(pos.shape[0]):
            valid = pos[slot][pos[slot] >= 0]
            if valid.size == 0:
                continue
            if self.allocator.share_prefix:
                # allocator.s_max (block-size padded) is the same modulus
                # cache_write_paged wraps with on device
                idxs = np.unique((valid % self.allocator.s_max)
                                 // self.allocator.block_size)
                forks += self.allocator.prepare_writes(slot, idxs)
            L = min(int(valid.max()) + 1, self.s_max)
            if self.allocator.needed(slot, L):
                if not self.allocator.extend(slot, L):
                    raise BlockPoolExhausted(
                        f"slot {slot} needs {self.allocator.needed(slot, L)}"
                        f" more KV blocks; pool has "
                        f"{self.allocator.free_blocks} free")
                self._tables_dirty = True
        self._flush_reclaims()
        if forks:
            self._tables_dirty = True
            self._apply_forks(forks)
        self._sync_tables()

    def _apply_forks(self, pairs: list[tuple[int, int]]):
        """Clone pool content for copy-on-write forks (src -> dst across
        every layer stack) in jitted, donated dispatches.  Pairs are
        chunked to the fixed (max_bps,) plan so jit specializations stay
        bounded regardless of how many forks one step needs."""
        W = self.allocator.max_blocks_per_slot
        for off in range(0, len(pairs), W):
            grp = pairs[off:off + W]
            src = np.full(W, -1, np.int32)
            dst = np.full(W, -1, np.int32)
            src[:len(grp)] = [s for s, _ in grp]
            dst[:len(grp)] = [d for _, d in grp]
            self.cache = _call_donated(self._copy_blocks, self.cache,
                                       jnp.asarray(src), jnp.asarray(dst))

    def alloc_prompt(self, slot: int, tokens, bids: list | None = None) -> int:
        """Allocate a freshly admitted slot's prompt blocks, deduping
        the leading full blocks against the prefix index.  Returns the
        number of leading prompt tokens now backed by shared blocks (0
        without ``share_prefix``) — the scheduler feeds only the suffix,
        from the first divergent token.  ``bids`` lets the caller pass
        the ``match_prefix`` probe it already ran for admission (valid
        as long as nothing was released in between).

        Matching, adoption, fresh allocation and registration all happen
        here, at admission, *before* the batched prompt feed: streams
        admitted into the same iteration dedupe against each other.
        This is safe only because the scheduler aligns prefill columns
        with absolute positions, so every sub-chunk of a split feed
        scatters a position range for all slots before any later
        sub-chunk's rows attend over it."""
        a = self.allocator
        assert a is not None, "alloc_prompt requires a paged engine"
        if bids is None or any(b not in a._rindex for b in bids):
            # re-probe: a block the admission probe matched may have
            # been reclaimed from the cached-free LRU in the interim
            bids = a.match_prefix(tokens)
        if bids:
            a.adopt_prefix(slot, bids)
            self._tables_dirty = True
        # continue the chain-hash walk into the content-addressed host
        # store: blocks a finished (or swapped) stream demoted to host
        # memory are adopted by H2D scatter instead of re-prefill
        host = []
        if self.swap_manager is not None:
            host = self.swap_manager.host_match_chain(tokens, len(bids))
        L = min(len(tokens), self.s_max)
        if a.needed(slot, L):
            if not a.extend(slot, L):
                raise BlockPoolExhausted(
                    f"prompt of {len(tokens)} tokens needs "
                    f"{a.needed(slot, L)} more KV blocks for slot {slot}; "
                    f"pool has {a.free_blocks} free — admission should "
                    f"have deferred this prefill")
            self._tables_dirty = True
        # reclaimed cached blocks must be wiped before the host scatter
        # or tail copy writes (and before the prompt feed reads them)
        self._flush_reclaims()
        if host:
            self.swap_manager.adopt_from_host(slot, len(bids), host)
        n_adopted = len(bids) + len(host)
        shared = n_adopted * a.block_size
        # partial-block tail: the longest matching row prefix of a
        # registered block is copied by value into the first divergent
        # block, so a prefix ending mid-block stops re-computing there
        tail = a.match_tail(tokens, n_adopted)
        if tail is not None:
            src_bid, rows = tail
            dst_bid = int(a.table[slot, n_adopted])
            W = a.max_blocks_per_slot
            src = np.full(W, -1, np.int32)
            dst = np.full(W, -1, np.int32)
            nrows = np.zeros(W, np.int32)
            src[0], dst[0], nrows[0] = src_bid, dst_bid, rows
            self.cache = _call_donated(self._copy_rows, self.cache,
                                       jnp.asarray(src), jnp.asarray(dst),
                                       jnp.asarray(nrows))
            a.tail_shared_tokens += rows
            shared += rows
        a.register_prefix(slot, tokens)
        return shared

    def readopt_prefix(self, slot: int, tokens) -> int:
        """Re-match a restarted (preempted/rewound) stream's leading
        blocks against the prefix index and adopt them into its freshly
        emptied slot — the restart analogue of ``alloc_prompt``'s
        dedupe.  The refeed then starts at the first unmatched token.
        Returns the number of re-adopted tokens (0 for dense engines or
        with sharing off)."""
        a = self.allocator
        if a is None or not a.share_prefix:
            return 0
        bids = a.match_prefix(tokens)
        if not bids:
            return 0
        a.adopt_prefix(slot, bids)
        self._tables_dirty = True
        self._sync_tables()
        return len(bids) * a.block_size

    def kv_cache_bytes(self) -> int:
        """Total bytes backing the KV cache (dense buffers or the whole
        block pool + tables)."""
        return int(sum(x.nbytes for x in jax.tree.leaves(self.cache)))

    def block_bytes(self) -> int:
        """Bytes one pool block occupies across all layers/stacks."""
        assert self.allocator is not None
        nb = self.allocator.n_blocks
        total = 0

        def walk(c):
            nonlocal total
            for k, v in c.items():
                if isinstance(v, dict):
                    walk(v)
                elif k in ("k", "v", "pos"):
                    total += v.nbytes // nb

        walk(self.cache)
        return total

    @property
    def pool_stats(self) -> dict:
        """Block-pool utilization telemetry (ServerStats / serve.py).
        Dense engines report their full reservation as in-use — that is
        the point of comparison: dense memory cost is ``max_slots x
        s_max`` regardless of actual sequence lengths."""
        total = self.kv_cache_bytes()
        if self.allocator is None:
            return dict(cache_impl="dense", kv_cache_bytes=total,
                        kv_bytes_in_use=total, kv_bytes_peak=total,
                        free_blocks=0, cached_free_blocks=0, used_blocks=0,
                        peak_used_blocks=0, n_blocks=0, block_size=0,
                        share_prefix=False, retain_prefix=False,
                        shared_blocks=0, dedupe_hit_blocks=0, cow_copies=0,
                        revived_blocks=0, reclaimed_blocks=0,
                        tail_shared_tokens=0,
                        swap=False, swapped_blocks=0, swap_out_bytes=0,
                        swap_in_bytes=0, host_store_blocks=0,
                        host_lru_blocks=0, host_dedupe_hits=0,
                        host_adopted_blocks=0, adopt_in_bytes=0,
                        demoted_blocks=0)
        a = self.allocator
        bb = self.block_bytes()
        sw = self.swap_manager
        return dict(cache_impl="paged", kv_cache_bytes=total,
                    kv_bytes_in_use=a.used_blocks * bb,
                    kv_bytes_peak=a.peak_used * bb,
                    free_blocks=a.free_blocks,
                    cached_free_blocks=a.cached_blocks,
                    used_blocks=a.used_blocks,
                    peak_used_blocks=a.peak_used, n_blocks=a.n_blocks,
                    block_size=a.block_size, share_prefix=a.share_prefix,
                    retain_prefix=a.retain_prefix,
                    shared_blocks=a.shared_blocks,
                    dedupe_hit_blocks=a.dedupe_hit_blocks,
                    cow_copies=a.cow_copies,
                    revived_blocks=a.revived_blocks,
                    reclaimed_blocks=a.reclaimed_blocks,
                    tail_shared_tokens=a.tail_shared_tokens,
                    swap=sw is not None,
                    swapped_blocks=sw.swapped_blocks if sw else 0,
                    swap_out_bytes=sw.swap_out_bytes if sw else 0,
                    swap_in_bytes=sw.swap_in_bytes if sw else 0,
                    host_store_blocks=sw.host_store_blocks if sw else 0,
                    host_lru_blocks=sw.host_lru_blocks if sw else 0,
                    host_dedupe_hits=sw.host_dedupe_hits if sw else 0,
                    host_adopted_blocks=sw.host_adopted_blocks if sw else 0,
                    adopt_in_bytes=sw.adopt_in_bytes if sw else 0,
                    demoted_blocks=sw.demoted_blocks if sw else 0)

    # -- bucketing ------------------------------------------------------
    def _bucket_of(self, n: int) -> int:
        for b in self.feed_buckets:
            if n <= b:
                return b
        return self.feed_buckets[-1]

    def _chunks(self, C: int):
        """Split a width-C feed into ladder-bounded sub-chunks."""
        cap = self.feed_buckets[-1]
        off = 0
        while off < C:
            yield off, min(cap, C - off)
            off += cap

    @staticmethod
    def _pad(arr, width, fill):
        pad = width - arr.shape[1]
        if pad <= 0:
            return arr
        return np.pad(arr, ((0, 0), (0, pad)), constant_values=fill)

    def _run_fused(self, tokens, positions, targets, sel_idx, last_local,
                   with_dists=True):
        """One fused sub-chunk; returns lazy (device) outputs.  Callers
        convert only what they need."""
        C = tokens.shape[1]
        Cb = self._bucket_of(C)
        self._specializations.add(
            ("fused" if with_dists else "fused_greedy", Cb))
        step = self._step if with_dists else self._step_greedy
        out, self.cache = _call_donated(
            step, self.params, self.cache,
            jnp.asarray(self._pad(tokens, Cb, 0), jnp.int32),
            jnp.asarray(self._pad(positions, Cb, -1), jnp.int32),
            jnp.asarray(self._pad(targets, Cb, -1), jnp.int32),
            jnp.asarray(sel_idx, jnp.int32),
            jnp.asarray(last_local, jnp.int32))
        return out

    # ------------------------------------------------------------------
    def feed(self, tokens: np.ndarray, positions: np.ndarray,
             targets: np.ndarray | None = None,
             sel_idx: np.ndarray | None = None,
             need_dists: bool = True) -> VerifyRows:
        """Chunked (partial) prefill over all slots, fused epilogue.

        tokens, positions: (max_slots, C) int32; positions == -1 marks
        padding/idle.  ``targets`` (max_slots, C) carries, per row, the
        token id whose probability the verifier will test (-1 = none);
        ``sel_idx`` (max_slots, R) the local indices of the rows whose
        p/top-k state the verifier will consume.  ``need_dists=False``
        (iterations whose batched requests are all greedy) selects the
        argmax-only step variant.  Only the fused rows cross to the host.
        """
        self._calls["feed"] += 1
        self._ensure_blocks(positions)
        B, C = tokens.shape
        R = self.verify_rows_max
        if targets is None:
            targets = np.full((B, C), -1, np.int32)
        if sel_idx is None:
            sel_idx = np.full((B, R), -1, np.int32)
        zeros = np.zeros(B, np.int32)
        tok_acc = np.zeros((B, R), np.int32)
        p_acc = np.zeros((B, R), np.float32)
        ki_acc = np.zeros((B, R, self.verify_top_k), np.int32)
        kv_acc = np.zeros((B, R, self.verify_top_k), np.float32)
        moved_bytes = 0
        for off, w in self._chunks(C):
            sl = slice(off, off + w)
            in_chunk = (sel_idx >= off) & (sel_idx < off + w)
            sub_sel = np.where(in_chunk, sel_idx - off, -1).astype(np.int32)
            res = self._run_fused(tokens[:, sl], positions[:, sl],
                                  targets[:, sl], sub_sel, zeros,
                                  with_dists=need_dists)
            if in_chunk.any():      # only selected rows cross to the host
                tok = np.asarray(res[0], np.int32)
                tok_acc = np.where(in_chunk, tok, tok_acc)
                moved_bytes += tok.nbytes
                if need_dists:
                    p_acc = np.where(in_chunk, np.asarray(res[1], np.float32),
                                     p_acc)
                    ki_acc = np.where(in_chunk[..., None],
                                      np.asarray(res[2], np.int32), ki_acc)
                    kv_acc = np.where(in_chunk[..., None],
                                      np.asarray(res[3], np.float32), kv_acc)
                    moved_bytes += (p_acc.nbytes + ki_acc.nbytes
                                    + kv_acc.nbytes)
        self.bytes_to_host += moved_bytes
        return VerifyRows(token_id=tok_acc, p_draft=p_acc,
                          topk_idx=ki_acc, topk_val=kv_acc)

    def prefill(self, tokens: np.ndarray, positions: np.ndarray) -> np.ndarray:
        """Prompt prefill over all slots.  Returns each slot's LAST valid
        row's full logits (max_slots, V) — gathered on device, one
        vocab-row per slot — and writes the cache.  Slots with no valid
        positions return zeros."""
        self._calls["prefill"] += 1
        self._ensure_blocks(positions)
        B, C = tokens.shape
        valid = positions >= 0
        # last valid column per slot (-1 = idle).  Valid entries need
        # not start at column 0: prefix-sharing feeds align columns with
        # absolute positions and pad the shared prefix
        last_col = np.where(valid.any(axis=1),
                            C - 1 - np.argmax(valid[:, ::-1], axis=1), -1)
        targets = np.full((B, C), -1, np.int32)
        no_sel = np.full((B, self.verify_rows_max), -1, np.int32)
        out = np.zeros((B, self.vocab), np.float32)
        for off, w in self._chunks(C):
            sl = slice(off, off + w)
            if not (positions[:, sl] >= 0).any():
                continue   # every slot's columns are shared-prefix padding
            local = np.clip(last_col - off, 0, w - 1).astype(np.int32)
            # only the last-row gather is consumed: the argmax-only step
            # variant suffices (no extra specialization, no wasted top-k)
            res = self._run_fused(tokens[:, sl], positions[:, sl],
                                  targets[:, sl], no_sel, local,
                                  with_dists=False)
            sel = (last_col >= off) & (last_col < off + w)
            if sel.any():
                # gather on device only the slots whose LAST prompt row
                # lives in this sub-chunk — the documented transfer is
                # one vocab row per prefilled slot, not (slots, V) per
                # sub-chunk
                idx = np.where(sel)[0]
                rows = np.asarray(
                    jnp.take(res[4], jnp.asarray(idx, jnp.int32), axis=0),
                    np.float32)
                out[idx] = rows
                self.bytes_to_host += rows.nbytes
        return out

    def decode(self, tokens: np.ndarray, positions: np.ndarray) -> DecodeRows:
        """One decode step for all slots. tokens/positions: (max_slots, 1).

        Returns fused last-token rows (argmax + top-k support)."""
        self._calls["decode"] += 1
        self._ensure_blocks(positions)
        self._specializations.add(("decode", 1))
        (tok, tk_i, tk_v), self.cache = _call_donated(
            self._decode, self.params, self.cache,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32))
        rows = DecodeRows(token_id=np.asarray(tok, np.int32),
                          topk_idx=np.asarray(tk_i, np.int32),
                          topk_val=np.asarray(tk_v, np.float32))
        self.bytes_to_host += rows.nbytes
        return rows

    # -- legacy/debug full-logits path ---------------------------------
    def feed_logits(self, tokens: np.ndarray,
                    positions: np.ndarray) -> np.ndarray:
        """Pre-fusion semantics: round-trip the full (max_slots, C, V)
        logits as host float32.  Bench baseline + identity tests."""
        self._calls["feed_logits"] += 1
        self._ensure_blocks(positions)
        parts = []
        for off, w in self._chunks(tokens.shape[1]):
            sl = slice(off, off + w)
            Cb = self._bucket_of(w)
            self._specializations.add(("raw", Cb))
            logits, self.cache = _call_donated(
                self._raw_verify, self.params, self.cache,
                jnp.asarray(self._pad(tokens[:, sl], Cb, 0), jnp.int32),
                jnp.asarray(self._pad(positions[:, sl], Cb, -1), jnp.int32))
            parts.append(np.asarray(logits[:, :w], np.float32))
        out = np.concatenate(parts, axis=1)
        self.bytes_to_host += out.nbytes
        return out

    def decode_logits(self, tokens: np.ndarray,
                      positions: np.ndarray) -> np.ndarray:
        """Pre-fusion decode: full last-token logits (max_slots, V)."""
        self._calls["decode_logits"] += 1
        self._ensure_blocks(positions)
        self._specializations.add(("raw_decode", 1))
        logits, self.cache = _call_donated(
            self._raw_decode, self.params, self.cache,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32))
        out = np.asarray(logits, np.float32)
        self.bytes_to_host += out.nbytes
        return out
