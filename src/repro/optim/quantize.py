"""Weight quantization for the on-device SLM (Synera §6.8 / Table 6).

Symmetric per-output-channel fake-quantization of matrix weights to
int8 / int4 (bitsandbytes-4bit / AWQ-class).  The quantized SLM runs
everywhere the fp SLM runs — Table 6 shows Synera's relative quality
gain is preserved under quantization (complementarity), which is the
claim we reproduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_quant(w, bits: int = 8):
    """Symmetric per-last-dim-channel quantize-dequantize."""
    if w.ndim < 2:
        return w
    qmax = 2.0 ** (bits - 1) - 1
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(wf / scale), -qmax - 1, qmax)
    return (q * scale).astype(w.dtype)


_QUANT_KEYS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
               "in_proj", "out_proj", "unembed"}


def quantize_params(params, bits: int = 8):
    """Quantize every projection matrix in a parameter pytree (norms,
    embeddings and biases stay full precision, as AWQ/BnB do)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        if name in _QUANT_KEYS and leaf.ndim >= 2:
            out.append(fake_quant(leaf, bits))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def speedup_factor(bits: int) -> float:
    """Modeled device-side speedup from weight-bandwidth reduction
    (memory-bound decode: time ~ weight bytes; paper Table 6 measures
    1.18x for BnB-4bit and 1.28x for AWQ end-to-end)."""
    return {8: 1.10, 4: 1.25}.get(bits, 1.0)
