"""Minimal AdamW + cosine schedule + global-norm clipping (pure pytrees).

No optax in this container; this is a faithful AdamW (decoupled weight
decay, bias correction) implemented over jax pytrees.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


class AdamW:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 max_grad_norm=1.0, schedule=None, state_dtype=jnp.float32):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.weight_decay = weight_decay
        self.max_grad_norm = max_grad_norm
        self.schedule = schedule or (lambda s: lr)
        # bf16 moment states for the 100B+ MoE archs: 400B-class training
        # does not fit f32 moments in a single v5e pod (EXPERIMENTS.md).
        self.state_dtype = jnp.dtype(state_dtype)

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        lr_t = self.schedule(step)
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            # decoupled weight decay only on matrices (>=2D)
            wd = self.weight_decay if p.ndim >= 2 else 0.0
            p_new = p.astype(jnp.float32) - lr_t * (delta + wd * p.astype(jnp.float32))
            return (p_new.astype(p.dtype), m_new.astype(self.state_dtype),
                    v_new.astype(self.state_dtype))

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), {
            "grad_norm": gnorm, "lr": lr_t}
