"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, register

MAMBA2_2_7B = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    source="arXiv:2405.21060",
))
