"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Llama-4 interleaves dense and MoE FFN layers (moe_every=2) and adds a
shared expert on MoE layers; router is top-1.  "Early fusion" means
multimodal tokens enter the same token stream — for the text-only dry-run
this is shape-transparent.
"""
from repro.configs.base import ModelConfig, register

LLAMA4_MAVERICK = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    rope_theta=500_000.0,
    n_experts=128,
    top_k=1,
    moe_every=2,           # alternate dense / MoE
    n_shared_experts=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
