"""llama-3.2-vision-90b [vlm] — cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

100 layers total: every 5th layer is a cross-attention layer over
precomputed vision-patch embeddings (frontend stubbed per assignment).
"""
from repro.configs.base import ModelConfig, register

LLAMA32_VISION_90B = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,    # 20 cross-attn layers out of 100
    n_image_tokens=1601,
    vision_dim=7680,       # frontend projector input dim (stub)
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
