"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, register

QWEN3_MOE_235B = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,            # per-expert FFN dim
    vocab=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    moe_every=1,          # every layer is MoE
    source="hf:Qwen/Qwen3-30B-A3B",
))
