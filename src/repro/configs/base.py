"""Model / run configuration for the repro framework.

One ``ModelConfig`` dataclass covers all six assigned architecture
families (dense, moe, vlm, audio, ssm, hybrid).  Every assigned
architecture registers a full-size config (used only for the multi-pod
dry-run via ShapeDtypeStructs) plus a ``reduced()`` variant that the CPU
smoke tests instantiate for real.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Input shapes (assigned; see system brief)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
    # Bonus shape exercising the paper's verification (partial prefill):
    # gamma=4 pending-verify tokens + uncached accepted tokens (chunk of 32)
    # over a 32k cached prefix.
    "verify_32k": InputShape("verify_32k", 32_768, 128, "verify"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Family selects the block layout.

    family:
      dense  -- decoder-only transformer (GQA, RoPE, optional qkv bias)
      moe    -- decoder-only with (possibly interleaved) MoE FFNs
      vlm    -- decoder-only with interleaved cross-attention image layers
      audio  -- encoder-decoder (whisper-like); conv/mel frontend stubbed
      ssm    -- attention-free Mamba2 (SSD)
      hybrid -- Mamba2 blocks + shared attention block every k layers
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""            # citation (hf model card / arXiv)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # 1 = every layer is MoE; 2 = alternate
    n_shared_experts: int = 0

    # --- VLM (cross-attention image layers) ---
    cross_attn_every: int = 0   # every k-th layer is cross-attn (0 = none)
    n_image_tokens: int = 1_601 # stub frontend output length
    vision_dim: int = 0         # frontend embedding dim (0 -> d_model)

    # --- audio (enc-dec) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1_500 # stub conv/mel frontend output length

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0          # 0 -> d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256        # SSD chunk length
    ssm_conv_width: int = 4

    # --- hybrid (zamba2-like) ---
    attn_every: int = 0         # shared attention block every k ssm layers

    # --- serving ---
    sliding_window: int = 8_192  # long-context decode window for attention archs
    max_verify_chunk: int = 32   # Sarathi-style partial-prefill chunk
    # KV cache layout: "dense" allocates (slots, s_max) up front; "paged"
    # backs slots with a shared block pool + per-slot block tables
    # (vLLM/PagedAttention layout) so memory scales with *actual* sequence
    # lengths and admission is bound by free blocks, not slot count.
    cache_impl: str = "dense"    # "dense" | "paged"
    kv_block_size: int = 16      # tokens per KV block when cache_impl="paged"
    # Paged Pallas kernel streaming (attn_impl="pallas" + cache_impl=
    # "paged"): each grid step fuses paged_block_kv // kv_block_size
    # consecutive block-table entries into one dense-sized DMA, and
    # paged_kv_splits > 1 adds flash-decode split-KV parallelism over
    # the sequence axis (partials merged by a jnp epilogue; =1 is
    # bit-identical to the single-pass kernel).
    paged_block_kv: int = 128    # fused KV tokens per paged grid step
    paged_kv_splits: int = 1     # parallel sequence splits (flash-decode)
    # Prefix sharing (paged only): dedupe identical leading full prompt
    # blocks across slots via ref-counted blocks; divergent writes into a
    # shared block fork a private copy (copy-on-write).
    share_prefix: bool = False
    # Prefix retention (implies share_prefix): released ref-0 prefix
    # blocks park on a cached-free LRU instead of returning to the free
    # list, so later sessions with the same prompt prefix re-adopt them
    # without recompute.  Reclaimed lazily under allocation pressure.
    retain_prefix: bool = False
    retain_blocks: int = 0       # cached-free LRU cap in blocks (0 = unbounded)
    # Host swap tier (paged only): preempted streams may be gathered to
    # host memory and scattered back instead of recompute-eviction when
    # the modeled D2H+H2D round trip beats the modeled re-prefill.
    kv_swap: bool = False
    host_swap_blocks: int = 0    # host store cap in blocks (0 = unbounded)
    # Content-addressed host store (kv_swap + share_prefix): host blocks
    # are keyed by prefix chain hash, deduped across streams, and new
    # sessions adopt matching host blocks via H2D scatter at admission.
    host_dedupe: bool = True
    # Eviction victim selection: "youngest" | "most-blocks" | "slo-aware"
    preempt_policy: str = "youngest"

    # --- implementation knobs (hillclimb levers) ---
    attn_impl: str = "blocked"   # "naive" | "blocked" (online-softmax scan)
    attn_block_kv: int = 1_024   # KV block for blocked attention
    remat: bool = True           # activation checkpointing on the layer scan
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family in ("ssm", "hybrid") and self.ssm_heads == 0:
            d_inner = self.ssm_expand * self.d_model
            object.__setattr__(self, "ssm_heads", d_inner // self.ssm_head_dim)
        if self.family == "vlm" and self.vision_dim == 0:
            object.__setattr__(self, "vision_dim", self.d_model)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def gqa_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads)) if n_heads else 0
        # keep the GQA character if the full config had it
        if n_heads and self.n_kv_heads < self.n_heads and n_kv == n_heads:
            n_kv = max(1, n_heads // 2)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads if n_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 1_024),
            sliding_window=256,
            attn_block_kv=128,
            ssm_head_dim=32,
            ssm_heads=0,
            ssm_chunk=32,
            remat=False,
            dtype="float32",
        )
        if self.n_experts:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["top_k"] = min(self.top_k, 2)
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
            kw["n_layers"] = 4  # 2 self + 2 cross rounds
            kw["n_image_tokens"] = 16
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
            kw["n_audio_frames"] = 24
        if self.attn_every:
            kw["attn_every"] = 2
            kw["n_layers"] = 4
        if self.family in ("ssm", "hybrid"):
            kw["ssm_state"] = min(self.ssm_state, 16)
        cfg = self.replace(**kw)
        return cfg

    # ------------------------------------------------------------------
    # Parameter counting (for roofline MODEL_FLOPS = 6 N D)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, dff, V = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        if self.qkv_bias:
            attn += (nh + 2 * nkv) * hd
        ffn = 3 * d * dff  # gated (SwiGLU)
        n = 0
        if self.family in ("dense", "moe", "vlm"):
            per_layer_norms = 2 * d
            for layer in range(self.n_layers):
                if self.family == "vlm" and self.cross_attn_every and (
                    (layer + 1) % self.cross_attn_every == 0
                ):
                    n += attn + ffn + per_layer_norms  # cross-attn layer
                    continue
                is_moe = (
                    self.family == "moe"
                    and self.n_experts
                    and (layer % self.moe_every == self.moe_every - 1)
                )
                if is_moe:
                    router = d * self.n_experts
                    experts = self.n_experts * 3 * d * dff
                    shared = self.n_shared_experts * 3 * d * dff
                    if active_only:
                        experts = self.top_k * 3 * d * dff
                    n += attn + router + experts + shared + per_layer_norms
                else:
                    dense_ff = ffn if self.family != "moe" else 3 * d * self.d_ff_dense
                    n += attn + dense_ff + per_layer_norms
        elif self.family == "audio":
            n += self.n_encoder_layers * (attn + ffn + 2 * d)
            n += self.n_layers * (2 * attn + ffn + 3 * d)  # self+cross
        elif self.family == "ssm":
            n += self.n_layers * (self._ssm_block_params() + d)
        elif self.family == "hybrid":
            n += self.n_layers * (self._ssm_block_params() + d)
            n_attn = self.n_layers // max(self.attn_every, 1)
            n += attn + ffn + 2 * d  # shared weights applied n_attn times
        n += V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        n += d  # final norm
        return n

    @property
    def d_ff_dense(self) -> int:
        # moe archs that interleave dense FFN layers use d_ff for experts
        # and this for the dense layers (same value unless overridden).
        return self.d_ff

    def _ssm_block_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        in_proj = d * (2 * di + 2 * st + nh)  # z, x, B, C, dt
        conv = self.ssm_conv_width * (di + 2 * st)
        out = di * d
        return in_proj + conv + out + 2 * nh + di  # A, D, gate norm

    # Active params (MoE-aware) for MODEL_FLOPS.
    def active_param_count(self) -> int:
        return self.param_count(active_only=True)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect: populate registry
    from repro import configs as _c  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)
