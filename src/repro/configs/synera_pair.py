"""The paper's own evaluation models (Table 3), at shapes deployable in
this framework.  The e2e examples instantiate *trainable* tiny variants of
this SLM/LLM pair on CPU; the full-size configs are dry-run targets like
the assigned archs.

SLM: llama-160m-like   [hf:JackFram/llama-160m]  (paper's Llama-160M draft)
LLM: llama-7b-like     [hf:meta-llama/Llama-2-7b] (paper's cloud verifier)
"""
from repro.configs.base import ModelConfig, register

SYNERA_SLM = register(ModelConfig(
    name="synera-slm-160m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=32000,
    rope_theta=10_000.0,
    source="hf:JackFram/llama-160m (paper Table 3)",
))

SYNERA_LLM = register(ModelConfig(
    name="synera-llm-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
    rope_theta=10_000.0,
    source="hf:meta-llama/Llama-2-7b (paper Table 3)",
))


def tiny_pair(vocab: int = 512):
    """Trainable SLM/LLM pair for CPU end-to-end experiments.

    The LLM is strictly deeper/wider so that, after training on the same
    synthetic corpus, it is measurably better — reproducing the paper's
    SLM/LLM capability gap at laptop scale.
    """
    slm = ModelConfig(
        name="tiny-slm", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=vocab,
        rope_theta=10_000.0, remat=False, dtype="float32",
    )
    llm = ModelConfig(
        name="tiny-llm", family="dense", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=8, d_ff=512, vocab=vocab,
        rope_theta=10_000.0, remat=False, dtype="float32",
    )
    return slm, llm
