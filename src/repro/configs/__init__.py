"""Config registry: importing this package registers every assigned
architecture (plus the paper's own SLM/LLM pair)."""
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    list_archs,
    register,
)

# Assigned architectures (10, spanning 6 families) -------------------------
from repro.configs.glm4_9b import GLM4_9B  # noqa: F401
from repro.configs.llama3_2_1b import LLAMA32_1B  # noqa: F401
from repro.configs.qwen3_moe_235b_a22b import QWEN3_MOE_235B  # noqa: F401
from repro.configs.llama_3_2_vision_90b import LLAMA32_VISION_90B  # noqa: F401
from repro.configs.llama4_maverick_400b_a17b import LLAMA4_MAVERICK  # noqa: F401
from repro.configs.whisper_medium import WHISPER_MEDIUM  # noqa: F401
from repro.configs.qwen2_1_5b import QWEN2_1_5B  # noqa: F401
from repro.configs.mamba2_2_7b import MAMBA2_2_7B  # noqa: F401
from repro.configs.zamba2_2_7b import ZAMBA2_2_7B  # noqa: F401
from repro.configs.qwen1_5_110b import QWEN15_110B  # noqa: F401

# Paper's own models -------------------------------------------------------
from repro.configs.synera_pair import SYNERA_LLM, SYNERA_SLM, tiny_pair  # noqa: F401

ASSIGNED_ARCHS = [
    "glm4-9b",
    "llama3.2-1b",
    "qwen3-moe-235b-a22b",
    "llama-3.2-vision-90b",
    "llama4-maverick-400b-a17b",
    "whisper-medium",
    "qwen2-1.5b",
    "mamba2-2.7b",
    "zamba2-2.7b",
    "qwen1.5-110b",
]
