"""whisper-medium [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356]

24 encoder + 24 decoder layers.  The mel-spectrogram + conv feature
extractor is stubbed: ``input_specs`` provides precomputed frame
embeddings of shape (batch, n_audio_frames, d_model).
"""
from repro.configs.base import ModelConfig, register

WHISPER_MEDIUM = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # MHA (kv=16)
    d_ff=4096,
    vocab=51865,
    rope_theta=10_000.0,    # we use RoPE in place of learned positions
    n_audio_frames=1500,
    source="arXiv:2212.04356",
))
