"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks. [arXiv:2411.15242]

54 mamba2 layers; one weight-shared attention+FFN block is applied every
6 mamba layers (9 applications of the same parameters).
"""
from repro.configs.base import ModelConfig, register

ZAMBA2_2_7B = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,          # shared attn block is MHA
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
))
