"""glm4-9b [dense] — RoPE, GQA. [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ModelConfig, register

GLM4_9B = register(ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    qkv_bias=True,       # GLM-4 uses QKV bias
    rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b",
))
