"""Checkpointing: save/load parameter pytrees (and optimizer state) as
.npz with path-encoded keys.  No external deps."""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

_SEP = "||"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like) -> object:
    """Load into the structure of ``like`` (a pytree with the same keys)."""
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = data[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
