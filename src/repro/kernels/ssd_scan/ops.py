"""jit'd wrapper for the SSD scan kernel (adds the D skip term the model
path applies, so it is drop-in for models/layers.mamba_block).

``interpret=None`` (the default) auto-detects the backend: compiled on
TPU, interpreter everywhere else — callers no longer thread the flag.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, D=None, *, chunk: int = 64, h0=None,
        interpret: bool | None = None):
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
                    interpret=interpret)
    if D is not None:
        y = y + (D[:, None] * x.astype(jnp.float32)).astype(y.dtype)
    return y, h
