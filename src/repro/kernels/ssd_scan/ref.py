"""Pure-jnp oracle for the SSD scan kernel: the chunked SSD from the
model path (models/layers.ssd_chunked), which is itself validated
against sequential recurrence in tests."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import ssd_chunked, ssd_decode


def ssd_scan_ref(x, dt, A, Bm, Cm, *, chunk: int = 64, h0=None):
    Q = min(chunk, x.shape[1])
    pad = (-x.shape[1]) % Q
    L = x.shape[1]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=Q, h0=h0)
    return y[:, :L], h


def ssd_sequential_ref(x, dt, A, Bm, Cm, h0=None):
    """Token-by-token recurrence — the ground truth both the kernel and
    the chunked path must match."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    h = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(L):
        y, h = ssd_decode(x[:, t:t + 1], dt[:, t:t + 1], A,
                          Bm[:, t:t + 1], Cm[:, t:t + 1], h)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), h
