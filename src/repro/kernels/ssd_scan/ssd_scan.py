"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

One program per (batch, head); the grid's minormost axis walks the
sequence chunk-by-chunk with the running SSM state (P x N, f32) carried
in VMEM scratch — the TPU-native shape of the SSD algorithm: the
intra-chunk dual quadratic form feeds the MXU (three (Q,Q)/(Q,N)/(Q,P)
matmuls per chunk), while the inter-chunk recurrence is a cheap
VMEM-resident rank-1-per-step update folded into the sequential grid.

Inputs are pre-activated (dt already softplus'd, conv+silu applied):
this kernel is the scan hot-spot only; the surrounding projections stay
in XLA where they fuse fine (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                y_ref, hout_ref, state_scr, *, n_chunks: int, chunk: int,
                use_h0: bool):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        if use_h0:
            state_scr[...] = h0_ref[0].astype(jnp.float32)
        else:
            state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)   # (Q,)
    Bm = b_ref[0].astype(jnp.float32)    # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)    # (Q, N)
    A = a_ref[0]                          # scalar (negative)

    dA = dt * A                           # (Q,)
    dAc = jnp.cumsum(dA)                  # (Q,)

    # intra-chunk dual form: L[i,j] = exp(dAc_i - dAc_j) for j <= i
    diff = dAc[:, None] - dAc[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(mask, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    M = scores * Lmat * dt[None, :]
    y_diag = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))    # (Q, P)

    # inter-chunk: contribution of the carried state
    state = state_scr[...]                                          # (P, N)
    y_off = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ()))) * jnp.exp(dAc)[:, None]

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h <- h * exp(sum dA) + x^T (B * decay * dt)
    decay_states = jnp.exp(dAc[-1] - dAc) * dt                      # (Q,)
    upd = jax.lax.dot_general(
        x, Bm * decay_states[:, None], (((0,), (0,)), ((), ())))    # (P, N)
    state_scr[...] = state * jnp.exp(dAc[-1]) + upd

    @pl.when(cb == n_chunks - 1)
    def _finish():
        hout_ref[0] = state_scr[...]


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, h0=None,
             interpret: bool | None = None):
    """x: (B, L, H, P); dt: (B, L, H) (softplus'd); A: (H,) negative;
    Bm, Cm: (B, L, N); h0: (B, H, P, N) or None.

    Returns (y (B, L, H, P), h_final (B, H, P, N)).  L is padded to a
    chunk multiple with dt=0 (a no-op on the state).
    """
    interpret = resolve_interpret(interpret)
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    n_chunks = Lp // Q

    xh = jnp.moveaxis(x, 2, 1).reshape(B * H, Lp, P)
    dth = jnp.moveaxis(dt, 2, 1).reshape(B * H, Lp)
    use_h0 = h0 is not None
    h0h = (h0.reshape(B * H, P, N).astype(jnp.float32) if use_h0
           else jnp.zeros((B * H, P, N), jnp.float32))

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=Q,
                               use_h0=use_h0)

    y, h_fin = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, Q), lambda bh, cb: (bh, cb)),
            pl.BlockSpec((1, Q, N), lambda bh, cb, H=H: (bh // H, cb, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, cb, H=H: (bh // H, cb, 0)),
            pl.BlockSpec((1,), lambda bh, cb, H=H: (bh % H,)),
            pl.BlockSpec((1, P, N), lambda bh, cb: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, P, N), lambda bh, cb: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Lp, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dth, Bm, Cm, A.astype(jnp.float32), h0h)

    y = jnp.moveaxis(y.reshape(B, H, Lp, P), 1, 2)[:, :L]
    return y, h_fin.reshape(B, H, P, N)
