# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def resolve_interpret(interpret):
    """Resolve a kernel entry point's ``interpret`` argument.

    ``None`` (the default everywhere) auto-detects: Pallas kernels
    compile natively on TPU and run in interpret mode on every other
    backend (structural validation on CPU CI).  An explicit bool always
    wins, so callers can force either mode.
    """
    if interpret is not None:
        return interpret
    import jax

    return jax.default_backend() != "tpu"
