"""Pallas TPU kernel: GQA decode attention (one token vs a long KV cache).

Decode is memory-bound: the whole KV cache streams through VMEM once per
step.  The GQA structure is the lever — this kernel processes all ``g``
query heads of one KV group per program, so each K/V block is loaded
from HBM ONCE and reused by the whole group (a g-fold HBM-traffic saving
over the per-q-head layout; cf. EXPERIMENTS.md §Perf decode analysis).

Grid = (batch * kv_heads, kv blocks); online-softmax state for the g
group heads lives in VMEM scratch across the sequential block axis.
Supports the circular sliding-window cache (kv_pos = -1 invalid slots,
``window`` for long-context decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, n_kvb: int, window: int,
                   scale: float):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale      # (g, hd)
    k = k_ref[0].astype(jnp.float32)               # (bkv, hd) loaded once
    v = v_ref[0].astype(jnp.float32)               # (bkv, hd)
    q_pos = qp_ref[0, 0]                           # scalar
    kv_pos = kp_ref[0]                             # (bkv,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bkv)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window:
        valid &= (q_pos - kv_pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == n_kvb - 1)
    def _finish():
        l = jnp.where(l_new == 0.0, 1.0, l_new)
        o_ref[0] = (acc_new / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     block_kv: int = 512, interpret: bool = True):
    """q: (B, nh, hd) one token per request; k, v: (B, S, nkv, hd);
    q_pos: (B,) int32 absolute position; kv_pos: (B, S) int32.

    Returns out (B, nh, hd).
    """
    B, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / (hd ** 0.5)

    bkv = min(block_kv, S)
    n_kvb = pl.cdiv(S, bkv)
    pad = n_kvb * bkv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad

    qg = q.reshape(B * nkv, g, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * nkv, S, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * nkv, S, hd)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, n_kvb=n_kvb, window=window,
                               scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B * nkv, n_kvb),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bk, sb: (bk, 0, 0)),
            pl.BlockSpec((1, bkv, hd), lambda bk, sb: (bk, sb, 0)),
            pl.BlockSpec((1, bkv, hd), lambda bk, sb: (bk, sb, 0)),
            pl.BlockSpec((1, 1), lambda bk, sb, nkv=nkv: (bk // nkv, 0)),
            pl.BlockSpec((1, bkv), lambda bk, sb, nkv=nkv: (bk // nkv, sb)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda bk, sb: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kh, vh, qp, kv_pos)

    return out.reshape(B, nh, hd)


# ---------------------------------------------------------------------------
# Block-table (paged) variant: the KV cache is a shared pool of
# fixed-size blocks; each slot's sequence is scattered across the pool
# and addressed through its block table (vLLM/PagedAttention layout).
# ---------------------------------------------------------------------------

def _decode_paged_kernel(bt_ref, q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, n_bt: int, nkv: int,
                         window: int, scale: float):
    bk = pl.program_id(0)
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    mapped = bt_ref[bk // nkv, sb] >= 0            # scalar: table entry valid
    q = q_ref[0].astype(jnp.float32) * scale       # (g, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bs, hd): one pool block
    v = v_ref[0, 0].astype(jnp.float32)
    q_pos = qp_ref[0, 0]
    kv_pos = kp_ref[0]                             # (bs,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bs)
    valid = mapped & (kv_pos >= 0) & (kv_pos <= q_pos)
    if window:
        valid &= (q_pos - kv_pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == n_bt - 1)
    def _finish():
        l = jnp.where(l_new == 0.0, 1.0, l_new)
        o_ref[0] = (acc_new / l[:, None]).astype(o_ref.dtype)


def decode_attention_paged(q, k_pool, v_pool, q_pos, pos_pool, block_tables,
                           *, window: int = 0, interpret: bool = True):
    """q: (B, nh, hd); k_pool, v_pool: (nb, bs, nkv, hd) shared block
    pool; q_pos: (B,) int32; pos_pool: (nb, bs) int32 (absolute position
    of each pool row, -1 = invalid); block_tables: (B, max_bps) int32
    pool block ids per slot (-1 = unmapped).

    The block table is a scalar-prefetch operand: the grid's KV axis
    walks the table, and each program's index map reads the table to DMA
    exactly that slot's pool block — no gathered (B, s_max) copy exists.
    Unmapped entries clamp to block 0 for the DMA and are masked wholesale
    in the kernel.  Returns out (B, nh, hd).
    """
    B, nh, hd = q.shape
    nb, bs, nkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = nh // nkv
    max_bps = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B * nkv, g, hd)
    kh = jnp.moveaxis(k_pool, 2, 1)                # (nb, nkv, bs, hd)
    vh = jnp.moveaxis(v_pool, 2, 1)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)
    bt = block_tables.astype(jnp.int32)

    kernel = functools.partial(_decode_paged_kernel, n_bt=max_bps, nkv=nkv,
                               window=window, scale=scale)

    def kv_map(bk, sb, bt, nkv=nkv):
        return (jnp.maximum(bt[bk // nkv, sb], 0), bk % nkv, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * nkv, max_bps),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bk, sb, bt: (bk, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), kv_map),
            pl.BlockSpec((1, 1, bs, hd), kv_map),
            pl.BlockSpec((1, 1),
                         lambda bk, sb, bt, nkv=nkv: (bk // nkv, 0)),
            pl.BlockSpec((1, bs),
                         lambda bk, sb, bt, nkv=nkv: (
                             jnp.maximum(bt[bk // nkv, sb], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda bk, sb, bt: (bk, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * nkv, g, hd), q.dtype),
        interpret=interpret,
    )(bt, qg, kh, vh, qp, pos_pool)

    return out.reshape(B, nh, hd)
