"""Pallas TPU kernel: GQA decode attention (one token vs a long KV cache).

Decode is memory-bound: the whole KV cache streams through VMEM once per
step.  The GQA structure is the lever — this kernel processes all ``g``
query heads of one KV group per program, so each K/V block is loaded
from HBM ONCE and reused by the whole group (a g-fold HBM-traffic saving
over the per-q-head layout; cf. EXPERIMENTS.md §Perf decode analysis).

Grid = (batch * kv_heads, kv blocks); online-softmax state for the g
group heads lives in VMEM scratch across the sequential block axis.
Supports the circular sliding-window cache (kv_pos = -1 invalid slots,
``window`` for long-context decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret
from repro.kernels import paged as PG

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, n_kvb: int, window: int,
                   scale: float):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale      # (g, hd)
    k = k_ref[0].astype(jnp.float32)               # (bkv, hd) loaded once
    v = v_ref[0].astype(jnp.float32)               # (bkv, hd)
    q_pos = qp_ref[0, 0]                           # scalar
    kv_pos = kp_ref[0]                             # (bkv,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bkv)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window:
        valid &= (q_pos - kv_pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == n_kvb - 1)
    def _finish():
        l = jnp.where(l_new == 0.0, 1.0, l_new)
        o_ref[0] = (acc_new / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     block_kv: int = 512, interpret: bool | None = None):
    """q: (B, nh, hd) one token per request; k, v: (B, S, nkv, hd);
    q_pos: (B,) int32 absolute position; kv_pos: (B, S) int32.

    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere).  Returns out (B, nh, hd).
    """
    interpret = resolve_interpret(interpret)
    B, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / (hd ** 0.5)

    bkv = min(block_kv, S)
    n_kvb = pl.cdiv(S, bkv)
    pad = n_kvb * bkv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad

    qg = q.reshape(B * nkv, g, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * nkv, S, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * nkv, S, hd)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, n_kvb=n_kvb, window=window,
                               scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B * nkv, n_kvb),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bk, sb: (bk, 0, 0)),
            pl.BlockSpec((1, bkv, hd), lambda bk, sb: (bk, sb, 0)),
            pl.BlockSpec((1, bkv, hd), lambda bk, sb: (bk, sb, 0)),
            pl.BlockSpec((1, 1), lambda bk, sb, nkv=nkv: (bk // nkv, 0)),
            pl.BlockSpec((1, bkv), lambda bk, sb, nkv=nkv: (bk // nkv, sb)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda bk, sb: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kh, vh, qp, kv_pos)

    return out.reshape(B, nh, hd)


# ---------------------------------------------------------------------------
# Block-table (paged) variant: the KV cache is a shared pool of
# fixed-size blocks; each slot's sequence is scattered across the pool
# and addressed through its block table (vLLM/PagedAttention layout).
#
# Streaming design (see kernels/paged.py and docs/architecture.md):
#   * fused DMA    -- each grid step issues ``fuse`` pool-block
#     descriptors (consecutive table entries) in one pipeline step, so
#     the KV axis runs ceil(max_bps / fuse) dense-sized transfers
#     instead of max_bps single-block ones;
#   * prefetch     -- the KV axis is marked ``arbitrary`` and every
#     descriptor's index map resolves the *next* step's table entries
#     through the scalar-prefetch table, so Mosaic's pipeline starts
#     step N+1's fused DMA while step N computes (double buffering);
#   * split-KV     -- a ``parallel`` split axis partitions the table
#     into contiguous runs; each split writes partial (m, l, acc) and
#     a jnp epilogue (PG.combine_splits) merges them — flash-decode,
#     so one long context uses splits * B * nkv programs, not B * nkv.
# ---------------------------------------------------------------------------

def _decode_paged_kernel(bt_ref, q_ref, *refs, fuse: int, spb: int,
                         max_bps: int, nkv: int, window: int, scale: float):
    k_refs = refs[:fuse]
    v_refs = refs[fuse:2 * fuse]
    qp_ref = refs[2 * fuse]
    kp_refs = refs[2 * fuse + 1:3 * fuse + 1]
    om_ref, ol_ref, oa_ref, m_scr, l_scr, acc_scr = refs[3 * fuse + 1:]

    bk = pl.program_id(0)
    sp = pl.program_id(1)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale       # (g, hd)
    q_pos = qp_ref[0, 0]
    slot = bk // nkv
    base = (sp * spb + sb) * fuse                  # first table entry here

    ks, vs, valids = [], [], []
    for j in range(fuse):
        # per-sub-block mapped mask (replaces the unfused kernel's
        # single ``mapped`` scalar): entry within table AND mapped
        mapped = PG.subblock_mapped(bt_ref, slot, base + j, max_bps)
        kv_pos = kp_refs[j][0]                     # (bs,)
        val = mapped & (kv_pos >= 0) & (kv_pos <= q_pos)
        if window:
            val &= (q_pos - kv_pos) < window
        ks.append(k_refs[j][0, 0])
        vs.append(v_refs[j][0, 0])
        valids.append(val)
    k = jnp.concatenate(ks, axis=0).astype(jnp.float32)   # (fuse*bs, hd)
    v = jnp.concatenate(vs, axis=0).astype(jnp.float32)
    valid = jnp.concatenate(valids, axis=0)               # (fuse*bs,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, fuse*bs)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == spb - 1)
    def _finish():
        om_ref[0, 0] = m_new
        ol_ref[0, 0] = l_new
        oa_ref[0, 0] = acc_new


def decode_attention_paged(q, k_pool, v_pool, q_pos, pos_pool, block_tables,
                           *, window: int = 0, block_kv: int | None = None,
                           kv_splits: int = 1,
                           interpret: bool | None = None):
    """q: (B, nh, hd); k_pool, v_pool: (nb, bs, nkv, hd) shared block
    pool; q_pos: (B,) int32; pos_pool: (nb, bs) int32 (absolute position
    of each pool row, -1 = invalid); block_tables: (B, max_bps) int32
    pool block ids per slot (-1 = unmapped).

    The block table is a scalar-prefetch operand: each grid step's index
    maps read ``fuse = block_kv // bs`` consecutive table entries and DMA
    exactly those pool blocks — no gathered (B, s_max) copy exists, and
    the KV axis walks ceil(max_bps / fuse) dense-sized fused transfers
    (``block_kv=None`` keeps legacy one-block steps).  ``kv_splits > 1``
    adds a parallel flash-decode split axis over the sequence; partial
    (m, l, acc) outputs are merged by :func:`repro.kernels.paged.
    combine_splits` (bit-identical to single-pass at ``kv_splits=1``).
    Unmapped / past-the-table entries clamp for the DMA and are masked
    per sub-block in the kernel.  Returns out (B, nh, hd).
    """
    interpret = resolve_interpret(interpret)
    B, nh, hd = q.shape
    nb, bs, nkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = nh // nkv
    max_bps = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)
    fuse, splits, spb = PG.fused_layout(max_bps, bs, block_kv, kv_splits)

    qg = q.reshape(B * nkv, g, hd)
    kh = jnp.moveaxis(k_pool, 2, 1)                # (nb, nkv, bs, hd)
    vh = jnp.moveaxis(v_pool, 2, 1)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)
    bt = block_tables.astype(jnp.int32)

    kernel = functools.partial(_decode_paged_kernel, fuse=fuse, spb=spb,
                               max_bps=max_bps, nkv=nkv, window=window,
                               scale=scale)

    def kv_map(j, nkv=nkv):
        def m(bk, sp, sb, bt):
            e = (sp * spb + sb) * fuse + j
            return (PG.table_entry(bt, bk // nkv, e, max_bps),
                    bk % nkv, 0, 0)
        return m

    def pos_map(j, nkv=nkv):
        def m(bk, sp, sb, bt):
            e = (sp * spb + sb) * fuse + j
            return (PG.table_entry(bt, bk // nkv, e, max_bps), 0)
        return m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * nkv, splits, spb),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bk, sp, sb, bt: (bk, 0, 0)),
            *[pl.BlockSpec((1, 1, bs, hd), kv_map(j)) for j in range(fuse)],
            *[pl.BlockSpec((1, 1, bs, hd), kv_map(j)) for j in range(fuse)],
            pl.BlockSpec((1, 1),
                         lambda bk, sp, sb, bt, nkv=nkv: (bk // nkv, 0)),
            *[pl.BlockSpec((1, bs), pos_map(j)) for j in range(fuse)],
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g), lambda bk, sp, sb, bt: (bk, sp, 0)),
            pl.BlockSpec((1, 1, g), lambda bk, sp, sb, bt: (bk, sp, 0)),
            pl.BlockSpec((1, 1, g, hd),
                         lambda bk, sp, sb, bt: (bk, sp, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )

    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * nkv, splits, g), jnp.float32),
            jax.ShapeDtypeStruct((B * nkv, splits, g), jnp.float32),
            jax.ShapeDtypeStruct((B * nkv, splits, g, hd), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, qg, *[kh] * fuse, *[vh] * fuse, qp, *[pos_pool] * fuse)

    out = PG.combine_splits(m, l, acc, q.dtype)
    return out.reshape(B, nh, hd)
