"""Pure-jnp oracle for GQA decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, q_pos, kv_pos, *, window: int = 0):
    """q: (B, nh, hd); k, v: (B, S, nkv, hd); q_pos: (B,); kv_pos: (B, S)."""
    B, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf) * scale
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window:
        valid &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vf)
    return out.astype(q.dtype)
