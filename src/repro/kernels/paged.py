"""Shared machinery for the block-table (paged) attention kernels.

The paged ``decode_gqa``/``partial_prefill`` variants stream a slot's
KV out of the shared block pool through its block table (vLLM /
PagedAttention layout).  Both kernels share the same three-layer
streaming design, and this module owns the pieces common to both:

* **Fused-DMA layout** (:func:`fused_layout`): each grid step DMAs
  ``fuse = block_kv // block_size`` consecutive block-table entries —
  ``fuse`` independent ``(bs, hd)`` pool-block descriptors issued
  together in one pipeline step — so the sequential KV axis shrinks
  from ``max_bps`` single-block steps to ``ceil(max_bps / fuse)``
  dense-sized transfers and the per-step DMA latency is amortized by
  the fusion factor.

* **Clamped table lookup** (:func:`table_entry`): the one shared
  index-map expression that turns a (possibly unmapped, possibly
  past-the-table) table entry into a safe pool block id for the DMA.
  Unmapped entries are masked wholesale in-kernel; the clamp only
  keeps the descriptor in bounds.

* **Split-KV combine** (:func:`combine_splits`): the flash-decode
  epilogue.  With ``kv_splits > 1`` the sequence axis is cut into
  ``splits`` contiguous runs of table entries, each owned by a
  *parallel* grid program that writes partial online-softmax state
  ``(m, l, acc)``; the epilogue merges the partials.  At
  ``kv_splits=1`` the merge degenerates to the single-pass
  normalization bit-for-bit (``w = exp(m - m) = 1`` exactly).

* **Grid accounting** (:func:`paged_grid_info`): the bench reads the
  fused grid shape from here so the step-count reduction is asserted,
  not eyeballed.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def table_entry(bt, slot, entry, max_bps: int):
    """Clamped block-table lookup shared by every paged index map.

    ``bt`` is the scalar-prefetch (B, max_bps) block table; ``entry``
    may be unmapped (-1) or — in a ragged final fused step — point past
    the table.  Both clamp to a valid pool block id (entry 0 / the last
    column) purely so the DMA descriptor stays in bounds; the kernel
    masks those sub-blocks out via :func:`subblock_mapped`.
    """
    return jnp.maximum(bt[slot, jnp.minimum(entry, max_bps - 1)], 0)


def subblock_mapped(bt_ref, slot, entry, max_bps: int):
    """In-kernel validity of one fused sub-block's table entry: it must
    lie inside the table AND be mapped.  Replaces the single ``mapped``
    scalar of the unfused kernels with one mask per sub-block."""
    return (entry < max_bps) & (
        bt_ref[slot, jnp.minimum(entry, max_bps - 1)] >= 0)


def fused_layout(max_bps: int, block_size: int, block_kv: int | None,
                 kv_splits: int = 1):
    """Resolve the fused/split grid layout for a paged kernel.

    Returns ``(fuse, splits, spb)``:
      * ``fuse``   — table entries DMAd per grid step
                     (``block_kv // block_size``, clamped to [1, max_bps];
                     ``block_kv=None`` keeps the legacy one-block steps)
      * ``splits`` — parallel flash-decode programs over the sequence
                     (requested ``kv_splits`` clamped so every split owns
                     at least one fused step)
      * ``spb``    — sequential fused steps per split

    ``splits * spb * fuse >= max_bps`` always; ragged tails (table
    lengths that are not a multiple of ``fuse`` or ``splits``) are
    handled by per-sub-block masking in the kernel.
    """
    fuse = 1 if block_kv is None else max(1, block_kv // block_size)
    fuse = min(fuse, max_bps)
    n_fused = -(-max_bps // fuse)
    splits = max(1, min(kv_splits, n_fused))
    spb = -(-n_fused // splits)
    return fuse, splits, spb


def paged_grid_info(max_bps: int, block_size: int, block_kv: int | None,
                    kv_splits: int = 1) -> dict:
    """Grid accounting for the bench: steps along the KV axis before
    and after fusion, and the resulting fused/split grid."""
    fuse, splits, spb = fused_layout(max_bps, block_size, block_kv,
                                     kv_splits)
    return dict(
        fuse=fuse,
        splits=splits,
        kv_steps=spb,                       # sequential steps per program
        kv_steps_total=splits * spb,        # KV-axis grid steps overall
        kv_steps_unfused=max_bps,           # the pre-fusion baseline
        tokens_per_step=fuse * block_size,
    )


def combine_splits(m, l, acc, out_dtype):
    """Flash-decode reduction over the split axis (axis 1).

    ``m``/``l``: (N, splits, R) float32 partial online-softmax max /
    normalizer; ``acc``: (N, splits, R, hd) float32 unnormalized
    accumulator.  An empty split carries (NEG_INF, 0, 0) and drops out:
    its weight underflows to 0 against any live split, and an all-empty
    row yields 0 exactly like the single-pass kernels (the ``l == 0``
    guard).  At ``splits == 1`` this is bit-identical to the in-kernel
    ``acc / l`` finish (``exp(m - m) = 1`` and the singleton sum are
    exact).
    """
    m_glob = m.max(axis=1)                                   # (N, R)
    w = jnp.exp(m - m_glob[:, None])                         # (N, S, R)
    l_glob = (w * l).sum(axis=1)                             # (N, R)
    acc_glob = (w[..., None] * acc).sum(axis=1)              # (N, R, hd)
    l_glob = jnp.where(l_glob == 0.0, 1.0, l_glob)
    return (acc_glob / l_glob[..., None]).astype(out_dtype)
