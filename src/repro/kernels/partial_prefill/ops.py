"""jit'd wrapper for the partial-prefill kernel.

``interpret=None`` (the default) auto-detects the backend: compiled on
TPU, interpreter everywhere else — callers no longer thread the flag.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.partial_prefill.partial_prefill import (
    partial_prefill_attention, partial_prefill_attention_paged)


@partial(jax.jit, static_argnames=("window", "block_kv", "interpret"))
def partial_prefill(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    block_kv: int = 512, interpret: bool | None = None):
    return partial_prefill_attention(q, k, v, q_pos, kv_pos, window=window,
                                     block_kv=block_kv, interpret=interpret)


@partial(jax.jit, static_argnames=("window", "block_kv", "kv_splits",
                                   "interpret"))
def partial_prefill_paged(q, k_pool, v_pool, q_pos, pos_pool, block_tables,
                          *, window: int = 0, block_kv: int | None = None,
                          kv_splits: int = 1, interpret: bool | None = None):
    return partial_prefill_attention_paged(q, k_pool, v_pool, q_pos,
                                           pos_pool, block_tables,
                                           window=window, block_kv=block_kv,
                                           kv_splits=kv_splits,
                                           interpret=interpret)
