"""Pure-jnp oracle for the partial-prefill kernel: identical semantics to
the serving path (layers.attention over a positional cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def partial_prefill_ref(q, k, v, q_pos, kv_pos, *, window: int = 0):
    B, C, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bchd,bshd->bhcs", q.astype(jnp.float32), kf) * scale
    valid = (kv_pos[:, None, :] >= 0) & (q_pos[:, :, None] >= 0) \
        & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        valid &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (padded queries): zero them like the kernel's
    # l==0 guard
    any_valid = valid.any(axis=-1)[:, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhcs,bshd->bchd", p, vf)
    return out.astype(q.dtype)
