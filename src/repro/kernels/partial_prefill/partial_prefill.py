"""Pallas TPU kernel: chunked partial prefill over a cached KV prefix.

This is the cloud-side hot op of Synera's verification-aware scheduler
(§4.5): a fixed-size chunk (Sarathi chunk, default 32) of
[device-accepted uncached tokens + pending-verify draft tokens] attends
over the request's long cached prefix plus itself (causal within the
chunk by absolute positions).

TPU design:
  * the chunk (C <= 32 queries) is VMEM-resident per (batch, head)
    program; the long KV cache streams through VMEM in blocks of
    ``block_kv`` (HBM -> VMEM pipelining via the grid's minormost axis);
  * online softmax (m, l, acc) lives in VMEM scratch carried across the
    sequential KV-block grid steps — the standard TPU flash-decode
    pattern;
  * positions arrive as explicit arrays (the cache is a circular buffer
    with -1 = invalid slots; padded queries carry position -1), so the
    mask logic is identical to the XLA serving path (layers.cache_write).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret
from repro.kernels import paged as PG

NEG_INF = -1e30


def _pp_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
               m_scr, l_scr, acc_scr, *, n_kvb: int, window: int,
               scale: float):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale      # (C, hd)
    k = k_ref[0].astype(jnp.float32)               # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)               # (bkv, hd)
    q_pos = qp_ref[0]                              # (C,) int32
    kv_pos = kp_ref[0]                             # (bkv,) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (C, bkv)
    valid = (kv_pos[None, :] >= 0) & (q_pos[:, None] >= 0) \
        & (kv_pos[None, :] <= q_pos[:, None])
    if window:
        valid &= (q_pos[:, None] - kv_pos[None, :]) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == n_kvb - 1)
    def _finish():
        l = jnp.where(l_new == 0.0, 1.0, l_new)
        o_ref[0] = (acc_new / l[:, None]).astype(o_ref.dtype)


def partial_prefill_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                              block_kv: int = 512,
                              interpret: bool | None = None):
    """q: (B, C, nh, hd); k, v: (B, S, nkv, hd); q_pos: (B, C) int32;
    kv_pos: (B, S) int32 (cache slot positions, -1 = invalid).

    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere).  Returns out (B, C, nh, hd).
    """
    interpret = resolve_interpret(interpret)
    B, C, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / (hd ** 0.5)

    bkv = min(block_kv, S)
    n_kvb = pl.cdiv(S, bkv)
    pad = n_kvb * bkv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad

    qh = jnp.moveaxis(q, 2, 1).reshape(B * nh, C, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * nkv, S, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * nkv, S, hd)

    kernel = functools.partial(_pp_kernel, n_kvb=n_kvb, window=window,
                               scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B * nh, n_kvb),
        in_specs=[
            pl.BlockSpec((1, C, hd), lambda bh, sb: (bh, 0, 0)),
            pl.BlockSpec((1, bkv, hd), lambda bh, sb, g=g: (bh // g, sb, 0)),
            pl.BlockSpec((1, bkv, hd), lambda bh, sb, g=g: (bh // g, sb, 0)),
            pl.BlockSpec((1, C), lambda bh, sb, nh=nh: (bh // nh, 0)),
            pl.BlockSpec((1, bkv), lambda bh, sb, nh=nh: (bh // nh, sb)),
        ],
        out_specs=pl.BlockSpec((1, C, hd), lambda bh, sb: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nh, C, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, q_pos, kv_pos)

    return jnp.moveaxis(out.reshape(B, nh, C, hd), 1, 2)


# ---------------------------------------------------------------------------
# Block-table (paged) variant: the cached prefix lives in a shared pool
# of fixed-size blocks addressed through per-slot block tables.
#
# Same streaming design as decode_gqa's paged variant (fused multi-block
# DMA + prefetch-friendly arbitrary KV axis + parallel split-KV with a
# jnp combine epilogue); shared machinery lives in kernels/paged.py.
# ---------------------------------------------------------------------------

def _pp_paged_kernel(bt_ref, q_ref, *refs, fuse: int, spb: int,
                     max_bps: int, nh: int, window: int, scale: float):
    k_refs = refs[:fuse]
    v_refs = refs[fuse:2 * fuse]
    qp_ref = refs[2 * fuse]
    kp_refs = refs[2 * fuse + 1:3 * fuse + 1]
    om_ref, ol_ref, oa_ref, m_scr, l_scr, acc_scr = refs[3 * fuse + 1:]

    bh = pl.program_id(0)
    sp = pl.program_id(1)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale       # (C, hd)
    q_pos = qp_ref[0]                              # (C,)
    slot = bh // nh
    base = (sp * spb + sb) * fuse                  # first table entry here

    ks, vs, valids = [], [], []
    for j in range(fuse):
        # per-sub-block mapped mask (replaces the unfused kernel's
        # single ``mapped`` scalar): entry within table AND mapped
        mapped = PG.subblock_mapped(bt_ref, slot, base + j, max_bps)
        kv_pos = kp_refs[j][0]                     # (bs,)
        val = mapped & (kv_pos[None, :] >= 0) & (q_pos[:, None] >= 0) \
            & (kv_pos[None, :] <= q_pos[:, None])
        if window:
            val &= (q_pos[:, None] - kv_pos[None, :]) < window
        ks.append(k_refs[j][0, 0])
        vs.append(v_refs[j][0, 0])
        valids.append(val)                         # (C, bs)
    k = jnp.concatenate(ks, axis=0).astype(jnp.float32)   # (fuse*bs, hd)
    v = jnp.concatenate(vs, axis=0).astype(jnp.float32)
    valid = jnp.concatenate(valids, axis=1)               # (C, fuse*bs)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (C, fuse*bs)
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == spb - 1)
    def _finish():
        om_ref[0, 0] = m_new
        ol_ref[0, 0] = l_new
        oa_ref[0, 0] = acc_new


def partial_prefill_attention_paged(q, k_pool, v_pool, q_pos, pos_pool,
                                    block_tables, *, window: int = 0,
                                    block_kv: int | None = None,
                                    kv_splits: int = 1,
                                    interpret: bool | None = None):
    """q: (B, C, nh, hd); k_pool, v_pool: (nb, bs, nkv, hd) shared block
    pool; q_pos: (B, C) int32; pos_pool: (nb, bs) int32; block_tables:
    (B, max_bps) int32 (-1 = unmapped).

    Same scalar-prefetch streaming design as ``decode_attention_paged``:
    each grid step DMAs ``fuse = block_kv // bs`` consecutive table
    entries (``block_kv=None`` keeps legacy one-block steps), the KV
    axis is prefetch-pipelined, and ``kv_splits > 1`` parallelizes over
    contiguous runs of the table with a jnp combine epilogue.  Unmapped
    or past-the-table entries clamp for the DMA and are masked per
    sub-block.  Returns out (B, C, nh, hd).
    """
    interpret = resolve_interpret(interpret)
    B, C, nh, hd = q.shape
    nb, bs, nkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = nh // nkv
    max_bps = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)
    fuse, splits, spb = PG.fused_layout(max_bps, bs, block_kv, kv_splits)

    qh = jnp.moveaxis(q, 2, 1).reshape(B * nh, C, hd)
    kh = jnp.moveaxis(k_pool, 2, 1)                # (nb, nkv, bs, hd)
    vh = jnp.moveaxis(v_pool, 2, 1)
    bt = block_tables.astype(jnp.int32)

    kernel = functools.partial(_pp_paged_kernel, fuse=fuse, spb=spb,
                               max_bps=max_bps, nh=nh, window=window,
                               scale=scale)

    def kv_map(j, nh=nh, g=g):
        def m(bh, sp, sb, bt):
            e = (sp * spb + sb) * fuse + j
            return (PG.table_entry(bt, bh // nh, e, max_bps),
                    (bh % nh) // g, 0, 0)
        return m

    def pos_map(j, nh=nh):
        def m(bh, sp, sb, bt):
            e = (sp * spb + sb) * fuse + j
            return (PG.table_entry(bt, bh // nh, e, max_bps), 0)
        return m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * nh, splits, spb),
        in_specs=[
            pl.BlockSpec((1, C, hd), lambda bh, sp, sb, bt: (bh, 0, 0)),
            *[pl.BlockSpec((1, 1, bs, hd), kv_map(j)) for j in range(fuse)],
            *[pl.BlockSpec((1, 1, bs, hd), kv_map(j)) for j in range(fuse)],
            pl.BlockSpec((1, C),
                         lambda bh, sp, sb, bt, nh=nh: (bh // nh, 0)),
            *[pl.BlockSpec((1, bs), pos_map(j)) for j in range(fuse)],
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C), lambda bh, sp, sb, bt: (bh, sp, 0)),
            pl.BlockSpec((1, 1, C), lambda bh, sp, sb, bt: (bh, sp, 0)),
            pl.BlockSpec((1, 1, C, hd),
                         lambda bh, sp, sb, bt: (bh, sp, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C, hd), jnp.float32),
        ],
    )

    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * nh, splits, C), jnp.float32),
            jax.ShapeDtypeStruct((B * nh, splits, C), jnp.float32),
            jax.ShapeDtypeStruct((B * nh, splits, C, hd), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, qh, *[kh] * fuse, *[vh] * fuse, q_pos, *[pos_pool] * fuse)

    out = PG.combine_splits(m, l, acc, q.dtype)
    return jnp.moveaxis(out.reshape(B, nh, C, hd), 1, 2)
