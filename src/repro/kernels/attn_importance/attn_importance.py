"""Pallas TPU kernel: attention with fused importance-score extraction.

Synera's importance score (§3.2) is the column-wise sum of the softmax
attention matrix — a quantity flash attention never materializes.  This
kernel fuses the column-sum accumulation into the attention computation
so the device SLM gets (outputs, importance) in one pass over VMEM.

Design for the TPU memory hierarchy (DESIGN.md §2):
  * the device SLM runs short contexts (S <= a few k), so K/V for one
    (batch, kv-head) are VMEM-resident: K,V = 2 * S * hd * 2B
    (S=2048, hd=64 -> 512 KiB), well under the ~16 MiB VMEM budget;
  * grid = (batch * heads, q blocks); the q-block axis is minormost so
    the importance output block (indexed by batch*head only) is revisited
    and accumulated across q blocks — the standard TPU reduction-grid
    pattern;
  * q/k blocks are MXU-aligned (block_q multiple of 128 lanes via hd
    padding in ops.py).

The full (block_q, S) score tile lives in VMEM (128 x 2048 f32 = 1 MiB),
so softmax is computed exactly per row — no online rescaling needed, and
the column sums are exact, not approximated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _attn_imp_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref, imp_ref, *,
                     causal: bool, scale: float):
    tb = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32) * scale            # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)                     # (S, hd)
    v = v_ref[0].astype(jnp.float32)                     # (S, hd)
    q_pos = qp_ref[0]                                    # (block_q,) int32
    kv_pos = kp_ref[0]                                   # (S,) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (block_q, S)

    # positions are explicit arrays: -1 marks padded query rows and
    # invalid (circular-cache) KV slots, exactly as in the XLA path
    valid = (kv_pos[None, :] >= 0) & (q_pos[:, None] >= 0)
    if causal:
        valid &= kv_pos[None, :] <= q_pos[:, None]
    s = jnp.where(valid, s, NEG_INF)

    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, axis=1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    p_norm = p / l                                       # (block_q, S)

    o_ref[0] = jax.lax.dot_general(
        p_norm, v, (((1,), (0,)), ((), ()))).astype(o_ref.dtype)

    contrib = jnp.sum(p_norm, axis=0)                    # (S,) column sums

    @pl.when(tb == 0)
    def _init():
        imp_ref[...] = jnp.zeros_like(imp_ref)

    imp_ref[0] += contrib.astype(imp_ref.dtype)


def attn_with_importance(q, k, v, q_pos=None, kv_pos=None, *,
                         causal: bool = True, q_offset: int = 0,
                         block_q: int = 128,
                         interpret: bool | None = None):
    """q: (B, Tq, nh, hd); k, v: (B, S, nkv, hd) with nh % nkv == 0.

    ``q_pos`` (B, Tq) / ``kv_pos`` (B, S) are optional explicit position
    arrays (-1 = padded query / invalid cache slot), so the kernel can
    serve the serving path's circular cache from inside a jit.  When
    omitted, contiguous positions starting at the static ``q_offset``
    are assumed (the original interface).

    Returns (out (B, Tq, nh, hd), importance (B, nh, S)) — importance is
    the per-head column sum of the softmax matrix over the Tq query rows.
    """
    interpret = resolve_interpret(interpret)
    B, Tq, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / (hd ** 0.5)

    bq = min(block_q, Tq)
    n_qb = pl.cdiv(Tq, bq)
    pad_q = n_qb * bq - Tq

    if q_pos is None:
        q_pos = q_offset + jnp.broadcast_to(
            jnp.arange(Tq, dtype=jnp.int32)[None], (B, Tq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    q_pos = q_pos.astype(jnp.int32)
    kv_pos = kv_pos.astype(jnp.int32)

    # (B*nh, Tq, hd) per-head layout
    qh = jnp.moveaxis(q, 2, 1).reshape(B * nh, Tq, hd)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * nkv, S, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * nkv, S, hd)

    kernel = functools.partial(_attn_imp_kernel, causal=causal, scale=scale)

    out, imp = pl.pallas_call(
        kernel,
        grid=(B * nh, n_qb),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, S, hd), lambda bh, tb, g=g: (bh // g, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda bh, tb, g=g: (bh // g, 0, 0)),
            pl.BlockSpec((1, bq), lambda bh, tb, nh=nh: (bh // nh, tb)),
            pl.BlockSpec((1, S), lambda bh, tb, nh=nh: (bh // nh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, S), lambda bh, tb: (bh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nh, n_qb * bq, hd), q.dtype),
            jax.ShapeDtypeStruct((B * nh, S), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, q_pos, kv_pos)

    out = out[:, :Tq].reshape(B, nh, Tq, hd)
    out = jnp.moveaxis(out, 1, 2)
    imp = imp.reshape(B, nh, S)
    return out, imp
