"""Pure-jnp oracle for the fused attention+importance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attn_with_importance_ref(q, k, v, *, causal: bool = True,
                             q_offset: int = 0):
    """q: (B, Tq, nh, hd); k, v: (B, S, nkv, hd).

    Returns (out (B, Tq, nh, hd), importance (B, nh, S)).
    """
    B, Tq, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32), kf) * scale
    if causal:
        q_pos = q_offset + jnp.arange(Tq)
        kv_pos = jnp.arange(S)
        mask = kv_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)  # (B, nh, Tq, S)
    out = jnp.einsum("bhts,bshd->bthd", p, vf).astype(q.dtype)
    imp = p.sum(axis=2)             # (B, nh, S) column sums
    return out, imp
