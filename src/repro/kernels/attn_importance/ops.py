"""jit'd public wrapper for the fused attention+importance kernel.

``interpret=None`` (the default) auto-detects the backend: compiled on
TPU, interpreter everywhere else — callers no longer thread the flag.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attn_importance.attn_importance import attn_with_importance


@partial(jax.jit, static_argnames=("causal", "q_offset", "interpret"))
def attention_with_importance(q, k, v, *, causal: bool = True,
                              q_offset: int = 0,
                              interpret: bool | None = None):
    """Kernel entry point.  Returns (out, paper_importance (B, S)) where
    the paper's importance score is the head-mean of the per-head column
    sums (Synera Fig 2)."""
    out, imp = attn_with_importance(q, k, v, causal=causal,
                                    q_offset=q_offset, interpret=interpret)
    return out, imp.mean(axis=1)
