"""Offline Synera-aware profiling (Synera §5).

For each SLM-LLM pair we run a calibration pass with *all* chunks
offloaded (the synergy orchestrator's profile mode) and collect one
``ChunkRecord`` per draft chunk.  From these we fit:

* ``c_th``  -- mean confidence of fully-accepted chunks (coarse filter cutoff)
* ``i_th``  -- budget -> percentile of the importance distribution
* ``alpha`` -- per-token acceptance probability, from the capped-geometric
               expectation E[#generated] = (1 - a^(g+1)) / (1 - a)
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict

import numpy as np

from repro.core.verifier import alpha_from_expected
from repro.core.offload import importance_from_percentile


@dataclass
class ChunkRecord:
    mean_conf: float
    mean_imp: float
    n_accepted: int
    gamma: int

    @property
    def fully_accepted(self) -> bool:
        return self.n_accepted >= self.gamma


@dataclass
class SyneraProfile:
    c_th: float
    alpha: float
    gamma: int
    importance_samples: list = field(default_factory=list)
    conf_samples: list = field(default_factory=list)

    def i_th_for_budget(self, budget: float) -> float:
        """Calibrated budget knob: bisect i_th so the EXPECTED offload
        rate over the calibration chunks matches the budget.

        The paper sets i_th at the (1-budget) percentile of the
        importance distribution (§5); because P_imp's sigmoid mid-band
        admits sub-threshold chunks and P_conf ~ 1 for the
        under-confident majority, the raw percentile overshoots the
        target rate ~3x.  When conf samples are available we solve for
        the i_th whose expected dual-metric rate equals the budget
        (same offline data, same knob semantics)."""
        imps = np.asarray(self.importance_samples, np.float64)
        if not self.conf_samples:
            return importance_from_percentile(imps, budget)
        from repro.core.offload import p_conf, p_imp
        confs = np.asarray(self.conf_samples, np.float64)
        pc = np.asarray(p_conf(confs, self.c_th))

        def rate(i_th):
            return float(np.mean(pc * np.asarray(p_imp(imps, i_th))))

        budget = float(np.clip(budget, 0.0, 1.0))
        lo, hi = 1e-9, float(imps.max()) * 4 + 1e-6
        if budget >= rate(lo):
            return lo
        if budget <= rate(hi):
            return hi
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if rate(mid) > budget:   # rate decreases as i_th grows
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(asdict(self), f)

    @classmethod
    def load(cls, path: str) -> "SyneraProfile":
        with open(path) as f:
            return cls(**json.load(f))


def fit_profile(records: list[ChunkRecord]) -> SyneraProfile:
    if not records:
        raise ValueError("no calibration records")
    gamma = records[0].gamma
    full = [r.mean_conf for r in records if r.fully_accepted]
    # cut-off confidence: mean confidence of fully-accepted chunks (§5);
    # fall back to a high quantile if nothing was fully accepted.
    if full:
        c_th = float(np.mean(full))
    else:
        c_th = float(np.quantile([r.mean_conf for r in records], 0.9))
    c_th = float(np.clip(c_th, 0.05, 0.999))

    # acceptance probability from expected accepted count (+1 bonus token
    # convention of Leviathan's E[#generated])
    e_gen = float(np.mean([min(r.n_accepted, gamma) for r in records])) + 1.0
    alpha = alpha_from_expected(e_gen, gamma)

    imps = [float(r.mean_imp) for r in records]
    confs = [float(r.mean_conf) for r in records]
    return SyneraProfile(c_th=c_th, alpha=alpha, gamma=gamma,
                         importance_samples=imps, conf_samples=confs)
