"""Progressive early exit inference (Synera §4.3).

* Layer-wise: compute a margin score (top-1 minus top-2 probability) from
  each eligible layer's hidden state; exit at the first layer whose margin
  exceeds the threshold.  Exits are allowed only in the last 25% of
  layers (conservative, per the paper).
* Sequence-wise: disable cloud offloading for t > gamma_seq * max_len.

On real hardware layer-wise exit saves wall-clock by skipping layers; on
this CPU container we compute all layers and *select* the exit layer,
reporting layers_executed to the latency model — the decision logic is
identical, only the saving is modeled (see DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class EarlyExitConfig:
    threshold: float = 0.7
    eligible_frac: float = 0.25   # exits allowed in the last 25% of layers
    seq_exit_frac: float = 0.8    # sequence-wise cutoff (gamma_seq)


def margin_scores(per_layer_logits):
    """per_layer_logits: (L, B, V) -> margin (L, B) = top1 - top2 prob."""
    probs = jax.nn.softmax(per_layer_logits.astype(jnp.float32), axis=-1)
    top2 = jax.lax.top_k(probs, 2)[0]  # (L, B, 2)
    return top2[..., 0] - top2[..., 1]


def pick_exit_layer(per_layer_logits, n_layers: int, ee: EarlyExitConfig):
    """Select the exit layer per batch element.

    per_layer_logits: (L, B, V) logits computed from the hidden state
    after each transformer layer (L = n_layers).
    Returns (exit_layer (B,) int32, exit_logits (B, V), margin (L, B)).
    """
    L = per_layer_logits.shape[0]
    margins = margin_scores(per_layer_logits)  # (L, B)
    first_eligible = int(jnp.ceil((1.0 - ee.eligible_frac) * n_layers)) - 1
    first_eligible = max(min(first_eligible, L - 1), 0)

    layer_idx = jnp.arange(L)[:, None]
    eligible = (layer_idx >= first_eligible) & (margins > ee.threshold)
    # first eligible layer, else last layer
    any_exit = eligible.any(axis=0)
    first_hit = jnp.argmax(eligible, axis=0)
    exit_layer = jnp.where(any_exit, first_hit, L - 1).astype(jnp.int32)

    B = per_layer_logits.shape[1]
    exit_logits = per_layer_logits[exit_layer, jnp.arange(B)]
    return exit_layer, exit_logits, margins


def layers_saved(exit_layer, n_layers: int):
    """Fraction of layer compute skipped (feeds the latency model)."""
    return (n_layers - 1 - exit_layer) / n_layers


def sequence_exit_active(t: int, max_len: int, ee: EarlyExitConfig) -> bool:
    """True when offloading should be disabled (tail of the generation)."""
    return t > ee.seq_exit_frac * max_len
