"""Speculative-decoding verification ("draft & verify", Fig 3).

The cloud LLM verifies a chunk of SLM draft tokens.  Two modes:

* greedy  -- accept while argmax(p_t) == draft_t; on mismatch the LLM's
             argmax replaces the rejected token.
* sample  -- Leviathan et al. 2023: accept x_t with prob min(1, p/q);
             on rejection resample from norm(max(p - q, 0)).

``verify_greedy`` / ``verify_sample`` are the host-numpy references
operating on full logits; ``verify_sample`` is exactly
distribution-preserving (we property-test this).  The serving hot path
uses the fused variants (``verify_greedy_ids`` / ``verify_sample_fused``)
consuming the engine's device-computed sparse rows: the accept test
still uses the EXACT full-softmax p(draft_t), but rejection resampling
and the bonus draw use the cloud's top-K sampling support — i.e. the
cloud's sampling method becomes top-K, exact w.r.t. the full
distribution only when K >= vocab (the property-tested regime).  That
is the same support-compression argument the paper makes for the §4.2
uplink, applied to the accelerator->host boundary.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class VerifyResult:
    n_accepted: int          # tokens of the draft accepted (0..gamma)
    corrected: int | None    # replacement token at the rejection position
    bonus: int | None        # extra token sampled when all gamma accepted
    tokens: list             # final verified continuation


def verify_greedy_ids(draft: np.ndarray, token_ids: np.ndarray) -> VerifyResult:
    """Greedy verification from per-row argmax ids alone (the fused
    on-device epilogue's output — no logits ever reach the host).

    draft: (gamma,) int; token_ids: (gamma+1,) int where entry t is
    argmax of the row predicting draft[t] (entry gamma predicts the
    bonus token)."""
    gamma = len(draft)
    tops = np.asarray(token_ids)
    n = 0
    while n < gamma and tops[n] == draft[n]:
        n += 1
    if n == gamma:
        bonus = int(tops[gamma])
        return VerifyResult(n, None, bonus, list(draft) + [bonus])
    return VerifyResult(n, int(tops[n]), None, list(draft[:n]) + [int(tops[n])])


def verify_greedy(draft: np.ndarray, p_logits: np.ndarray) -> VerifyResult:
    """Host-numpy reference: draft (gamma,) int; p_logits (gamma+1, V)
    LLM logits where row t predicts draft[t] (row gamma predicts the
    bonus token).  Kept as the oracle the fused path is tested against."""
    return verify_greedy_ids(draft, np.argmax(p_logits, axis=-1))


def _softmax(x):
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def verify_sample(draft: np.ndarray, p_logits: np.ndarray,
                  q_probs_sparse, rng: np.random.Generator) -> VerifyResult:
    """Stochastic speculative verification.

    q_probs_sparse: list of (idx (k,), val (k,)) per draft position — the
    *compressed* SLM distribution (core/compression.py).  The values are
    the renormalized sampling distribution the device actually used, so
    verification is lossless w.r.t. the intended sampling method (§4.2).
    """
    gamma = len(draft)
    V = p_logits.shape[-1]
    p = _softmax(p_logits.astype(np.float64))
    for t in range(gamma):
        idx, val = q_probs_sparse[t]
        qt = dict(zip(np.asarray(idx).tolist(), np.asarray(val, np.float64).tolist()))
        q_x = qt.get(int(draft[t]), 1e-12)
        p_x = p[t, int(draft[t])]
        if rng.random() < min(1.0, p_x / q_x):
            continue
        # rejected at t: resample from norm(max(p - q, 0))
        residual = p[t].copy()
        for j, qv in qt.items():
            residual[j] = max(residual[j] - qv, 0.0)
        s = residual.sum()
        if s <= 0:
            corrected = int(np.argmax(p[t]))
        else:
            corrected = int(rng.choice(V, p=residual / s))
        return VerifyResult(t, corrected, None, list(draft[:t]) + [corrected])
    bonus = int(rng.choice(V, p=p[gamma]))
    return VerifyResult(gamma, None, bonus, list(draft) + [bonus])


def verify_sample_fused(draft: np.ndarray, p_draft: np.ndarray,
                        topk_rows, q_probs_sparse,
                        rng: np.random.Generator, vocab: int) -> VerifyResult:
    """Stochastic verification from the fused epilogue's sparse rows.

    p_draft: (gamma,) EXACT softmax probability of each draft token under
    the full-vocab LLM row (gathered on device) — the accept test is
    therefore identical to :func:`verify_sample`.
    topk_rows: list of (idx (K,), val (K,)) per row, len gamma+1 — the
    LLM's top-K sampling support.  Rejection resampling draws from
    norm(max(p_K - q, 0)) and the bonus token from p_K: exact when
    K >= vocab, otherwise the cloud's sampling method is top-K (the same
    support-compression argument as the §4.2 uplink).
    Consumes ``rng`` in the same order as :func:`verify_sample`, so the
    two produce identical decisions when K >= vocab.
    """
    gamma = len(draft)
    for t in range(gamma):
        idx, val = q_probs_sparse[t]
        qt = dict(zip(np.asarray(idx).tolist(),
                      np.asarray(val, np.float64).tolist()))
        q_x = qt.get(int(draft[t]), 1e-12)
        p_x = float(p_draft[t])
        if rng.random() < min(1.0, p_x / q_x):
            continue
        # rejected at t: resample from norm(max(p - q, 0)).  Tokens
        # outside the top-K support carry p = 0 under top-K sampling,
        # so the residual support is a subset of the top-K support.
        pi = np.asarray(topk_rows[t][0])
        pv = np.asarray(topk_rows[t][1], np.float64)
        if len(pi) >= vocab:
            # full support: dense form, rng-draw-identical to the
            # verify_sample reference (the property-tested regime)
            residual = np.zeros(vocab, np.float64)
            residual[pi] = pv
            for j, qv in qt.items():
                residual[j] = max(residual[j] - qv, 0.0)
            s = residual.sum()
            corrected = (int(pi[np.argmax(pv)]) if s <= 0
                         else int(rng.choice(vocab, p=residual / s)))
        else:
            # hot path: O(K) on the support — no vocab-sized host work
            res = pv - np.array([qt.get(int(j), 0.0) for j in pi])
            res = np.maximum(res, 0.0)
            s = res.sum()
            corrected = (int(pi[np.argmax(pv)]) if s <= 0
                         else int(pi[rng.choice(len(pi), p=res / s)]))
        return VerifyResult(t, corrected, None, list(draft[:t]) + [corrected])
    pi = np.asarray(topk_rows[gamma][0])
    pv = np.asarray(topk_rows[gamma][1], np.float64)
    if len(pi) >= vocab:
        p = np.zeros(vocab, np.float64)
        p[pi] = pv
        bonus = int(rng.choice(vocab, p=p / p.sum()))
    else:
        bonus = int(pi[rng.choice(len(pi), p=pv / pv.sum())])
    return VerifyResult(gamma, None, bonus, list(draft) + [bonus])


def fused_row_from_logits(logits_row: np.ndarray, target: int, top_k: int):
    """Host mirror of models/steps.fused_verify_epilogue for ONE
    full-logits row — used when the pre-draft row was produced by a
    prompt prefill (whose target token was unknown at prefill time).

    Returns (token_id, p_target, topk_idx, topk_val)."""
    lf = np.asarray(logits_row, np.float32)
    e = np.exp(lf - lf.max(), dtype=np.float32)  # f32 on purpose: mirrors
    probs = e / e.sum(dtype=np.float32)          # the device epilogue
    k = max(1, min(top_k, lf.shape[-1]))
    # O(V) partition + O(k log k) sort, not a full-vocab argsort
    part = np.argpartition(-probs, k - 1)[:k]
    order = part[np.argsort(-probs[part], kind="stable")].astype(np.int32)
    p_t = float(probs[target]) if target is not None and target >= 0 else 0.0
    return (int(np.argmax(lf)), p_t, order, probs[order].astype(np.float32))


# ---------------------------------------------------------------------------
# Batched jnp variant (used by the engine's fused verification path and by
# the property tests).
# ---------------------------------------------------------------------------

import jax
import jax.numpy as jnp


def verify_greedy_batched(draft, p_logits):
    """draft: (B, gamma); p_logits: (B, gamma+1, V).

    Returns (n_accepted (B,), corrected (B,), bonus (B,)) where
    ``corrected`` is the replacement at the rejection position (valid when
    n_accepted < gamma) and ``bonus`` the extra token (valid otherwise).
    """
    gamma = draft.shape[1]
    tops = jnp.argmax(p_logits, axis=-1)  # (B, gamma+1)
    match = tops[:, :gamma] == draft      # (B, gamma)
    # first mismatch position (gamma if none)
    n_acc = jnp.where(match.all(axis=1), gamma,
                      jnp.argmin(match.astype(jnp.int32), axis=1))
    corrected = jnp.take_along_axis(
        tops, jnp.minimum(n_acc, gamma - 1)[:, None], axis=1)[:, 0]
    bonus = tops[:, gamma]
    return n_acc, corrected, bonus


def expected_accepted(alpha: float, gamma: int) -> float:
    """E[#generated] for per-token acceptance alpha (capped geometric,
    Leviathan eq. 1): (1 - alpha^{gamma+1}) / (1 - alpha)."""
    if alpha >= 1.0:
        return gamma + 1.0
    return (1.0 - alpha ** (gamma + 1)) / (1.0 - alpha)


def alpha_from_expected(e_gen: float, gamma: int) -> float:
    """Invert expected_accepted by bisection (profiling §5)."""
    lo, hi = 1e-6, 1.0 - 1e-9
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if expected_accepted(mid, gamma) < e_gen:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
