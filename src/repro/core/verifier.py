"""Speculative-decoding verification ("draft & verify", Fig 3).

The cloud LLM verifies a chunk of SLM draft tokens.  Two modes:

* greedy  -- accept while argmax(p_t) == draft_t; on mismatch the LLM's
             argmax replaces the rejected token.
* sample  -- Leviathan et al. 2023: accept x_t with prob min(1, p/q);
             on rejection resample from norm(max(p - q, 0)).  Exactly
             distribution-preserving (we property-test this).

Host-side numpy implementation (the scheduler calls it per request) plus
a batched jnp implementation used by tests and the batched engine path.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class VerifyResult:
    n_accepted: int          # tokens of the draft accepted (0..gamma)
    corrected: int | None    # replacement token at the rejection position
    bonus: int | None        # extra token sampled when all gamma accepted
    tokens: list             # final verified continuation


def verify_greedy(draft: np.ndarray, p_logits: np.ndarray) -> VerifyResult:
    """draft: (gamma,) int; p_logits: (gamma+1, V) LLM logits where row t
    predicts draft[t] (row gamma predicts the bonus token)."""
    gamma = len(draft)
    tops = np.argmax(p_logits, axis=-1)
    n = 0
    while n < gamma and tops[n] == draft[n]:
        n += 1
    if n == gamma:
        bonus = int(tops[gamma])
        return VerifyResult(n, None, bonus, list(draft) + [bonus])
    return VerifyResult(n, int(tops[n]), None, list(draft[:n]) + [int(tops[n])])


def _softmax(x):
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def verify_sample(draft: np.ndarray, p_logits: np.ndarray,
                  q_probs_sparse, rng: np.random.Generator) -> VerifyResult:
    """Stochastic speculative verification.

    q_probs_sparse: list of (idx (k,), val (k,)) per draft position — the
    *compressed* SLM distribution (core/compression.py).  The values are
    the renormalized sampling distribution the device actually used, so
    verification is lossless w.r.t. the intended sampling method (§4.2).
    """
    gamma = len(draft)
    V = p_logits.shape[-1]
    p = _softmax(p_logits.astype(np.float64))
    for t in range(gamma):
        idx, val = q_probs_sparse[t]
        qt = dict(zip(np.asarray(idx).tolist(), np.asarray(val, np.float64).tolist()))
        q_x = qt.get(int(draft[t]), 1e-12)
        p_x = p[t, int(draft[t])]
        if rng.random() < min(1.0, p_x / q_x):
            continue
        # rejected at t: resample from norm(max(p - q, 0))
        residual = p[t].copy()
        for j, qv in qt.items():
            residual[j] = max(residual[j] - qv, 0.0)
        s = residual.sum()
        if s <= 0:
            corrected = int(np.argmax(p[t]))
        else:
            corrected = int(rng.choice(V, p=residual / s))
        return VerifyResult(t, corrected, None, list(draft[:t]) + [corrected])
    bonus = int(rng.choice(V, p=p[gamma]))
    return VerifyResult(gamma, None, bonus, list(draft) + [bonus])


# ---------------------------------------------------------------------------
# Batched jnp variant (used by the engine's fused verification path and by
# the property tests).
# ---------------------------------------------------------------------------

import jax
import jax.numpy as jnp


def verify_greedy_batched(draft, p_logits):
    """draft: (B, gamma); p_logits: (B, gamma+1, V).

    Returns (n_accepted (B,), corrected (B,), bonus (B,)) where
    ``corrected`` is the replacement at the rejection position (valid when
    n_accepted < gamma) and ``bonus`` the extra token (valid otherwise).
    """
    gamma = draft.shape[1]
    tops = jnp.argmax(p_logits, axis=-1)  # (B, gamma+1)
    match = tops[:, :gamma] == draft      # (B, gamma)
    # first mismatch position (gamma if none)
    n_acc = jnp.where(match.all(axis=1), gamma,
                      jnp.argmin(match.astype(jnp.int32), axis=1))
    corrected = jnp.take_along_axis(
        tops, jnp.minimum(n_acc, gamma - 1)[:, None], axis=1)[:, 0]
    bonus = tops[:, gamma]
    return n_acc, corrected, bonus


def expected_accepted(alpha: float, gamma: int) -> float:
    """E[#generated] for per-token acceptance alpha (capped geometric,
    Leviathan eq. 1): (1 - alpha^{gamma+1}) / (1 - alpha)."""
    if alpha >= 1.0:
        return gamma + 1.0
    return (1.0 - alpha ** (gamma + 1)) / (1.0 - alpha)


def alpha_from_expected(e_gen: float, gamma: int) -> float:
    """Invert expected_accepted by bisection (profiling §5)."""
    lo, hi = 1e-6, 1.0 - 1e-9
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if expected_accepted(mid, gamma) < e_gen:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
