"""Stall-free parallel inference (Synera §4.4).

While the cloud verifies a draft chunk, the device predicts the rejection
position r* from a confidence-adjusted capped-geometric distribution and
speculatively continues generation from a corrected prefix.  When the
cloud's verdict matches the prediction, the speculative tokens are kept
and the round-trip stall is masked.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def rejection_distribution(confidences: np.ndarray, alpha: float) -> np.ndarray:
    """P(r = t) for t in {0..gamma}; t = gamma means full acceptance.

    Base: capped geometric P_base(r=t) = (1-alpha) alpha^t (t < gamma),
    alpha^gamma at t = gamma.  Adjusted by each draft token's confidence:
    P_adj(r=t) = P_base(r=t) * (1 - c_t) — high confidence lowers the
    rejection probability at t (Fig 4a).  Normalized.
    """
    gamma = len(confidences)
    base = np.array([(1 - alpha) * alpha ** t for t in range(gamma)] +
                    [alpha ** gamma], np.float64)
    adj = base.copy()
    adj[:gamma] *= (1.0 - np.asarray(confidences, np.float64))
    # full-acceptance mass scales with the chunk's overall confidence
    adj[gamma] *= max(float(np.mean(confidences)), 1e-6)
    s = adj.sum()
    return adj / s if s > 0 else np.full(gamma + 1, 1.0 / (gamma + 1))


def predict_rejection(confidences: np.ndarray, alpha: float,
                      rng: np.random.Generator) -> int:
    """Sample r* from the adjusted distribution."""
    p = rejection_distribution(confidences, alpha)
    return int(rng.choice(len(p), p=p))


@dataclass
class PIState:
    """One in-flight parallel-inference speculation.

    ``alt_token`` is the token PI placed at position r*: for r* < gamma
    the sampled replacement for the predicted-rejected draft token; for
    r* == gamma (predicted full acceptance) the SLM's own prediction of
    the LLM's bonus token.
    """
    r_star: int                 # predicted rejection position
    alt_token: int              # token PI placed at r*
    tokens: list = None         # speculative continuation generated during the stall


def choose_alternative(top3_idx: np.ndarray, top3_val: np.ndarray,
                       draft_token: int, rng: np.random.Generator) -> int:
    """Pick the replacement token at the predicted rejection position from
    the SLM's top-3 candidates, excluding the rejected draft token."""
    mask = top3_idx != draft_token
    idx = top3_idx[mask]
    val = np.asarray(top3_val, np.float64)[mask]
    if len(idx) == 0:
        return int(draft_token)
    val = val / val.sum()
    return int(rng.choice(idx, p=val))


def merge(pi: PIState, n_accepted_cloud: int, cloud_token_at_r: int,
          gamma: int):
    """Compare prediction with the cloud verdict (§4.4).

    ``cloud_token_at_r`` is the token the cloud placed at r_cloud: the
    corrected token on rejection, or the bonus token on full acceptance.

    Returns (adopt_pi: bool, position_hit: bool).  ``position_hit`` is the
    paper's reported hit-rate metric (r* == r_cloud); adopting the PI
    tokens additionally requires the token at r* to match, so the merged
    stream is always identical to the vanilla pipeline's output.
    """
    r_cloud = n_accepted_cloud
    position_hit = (pi.r_star == r_cloud)
    if not position_hit:
        return False, False
    return pi.alt_token == cloud_token_at_r, True
