"""Compression before transmission (Synera §4.2).

The verifier needs the draft tokens plus the SLM's probability
distribution at each draft position.  Transmitting the full distribution
is tens of thousands of floats (e.g. 32,000 for Llama-2); Synera sends
only the support of the *intended sampling method* (top-1 for greedy,
top-k, or top-p), which is lossless for verification and >99.5% smaller.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CompressedDist:
    idx: np.ndarray   # (k,) int32 token ids in the support
    val: np.ndarray   # (k,) float16 renormalized probabilities

    @property
    def nbytes(self) -> int:
        return int(self.idx.nbytes + self.val.nbytes)


def _softmax(x):
    x = x - x.max()
    e = np.exp(x, dtype=np.float64)
    return e / e.sum()


def compress(logits: np.ndarray, method: str = "top_k", k: int = 8,
             top_p: float = 0.9, temperature: float = 1.0) -> CompressedDist:
    """Compress one position's distribution to its sampling support."""
    probs = _softmax(logits.astype(np.float64) / max(temperature, 1e-6))
    if method == "greedy":
        idx = np.array([int(np.argmax(probs))], np.int32)
        val = np.array([1.0], np.float16)
        return CompressedDist(idx, val)
    if method == "top_k":
        idx = np.argpartition(probs, -k)[-k:].astype(np.int32)
        idx = idx[np.argsort(-probs[idx])]
    elif method == "top_p":
        order = np.argsort(-probs)
        cum = np.cumsum(probs[order])
        cut = int(np.searchsorted(cum, top_p) + 1)
        idx = order[:cut].astype(np.int32)
    else:
        raise ValueError(method)
    val = probs[idx]
    val = (val / val.sum()).astype(np.float16)
    return CompressedDist(idx, val)


def decompress(c: CompressedDist, vocab: int) -> np.ndarray:
    out = np.zeros(vocab, np.float64)
    out[c.idx] = c.val.astype(np.float64)
    s = out.sum()
    return out / s if s > 0 else out


def full_dist_bytes(vocab: int, dtype_bytes: int = 4) -> int:
    return vocab * dtype_bytes


def chunk_payload_bytes(dists: list[CompressedDist], n_tokens: int,
                        *, compressed: bool = True, vocab: int = 32000) -> int:
    """Uplink payload for one verification request: draft token ids +
    (compressed or full) distributions + small header."""
    header = 32
    tok_bytes = 4 * n_tokens
    if compressed:
        dist_bytes = sum(d.nbytes for d in dists)
    else:
        dist_bytes = full_dist_bytes(vocab) * len(dists)
    return header + tok_bytes + dist_bytes


def compression_ratio(dists: list[CompressedDist], vocab: int) -> float:
    full = full_dist_bytes(vocab) * len(dists)
    comp = sum(d.nbytes for d in dists)
    return 1.0 - comp / max(full, 1)
