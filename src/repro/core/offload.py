"""Selective token-level offloading (Synera §4.2).

Two-stage dispatch over draft chunks of gamma tokens:
  1. ``p_conf`` (coarse): scaled sigmoid over the chunk's mean confidence
     (top-1 probability).  Retains the ~15% highly-confident chunks.
  2. ``p_imp``  (fine):   three-tier scaled sigmoid over the chunk's mean
     attention importance (column sums).  ``i_th`` is the runtime budget
     knob.

Both are exactly the paper's equations (Fig 9) with k=10, theta=-10.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def p_conf(c, c_th: float, k: float = 10.0):
    """Confidence dispatch probability.

    P_conf(c) = 1                          if c <= c_th
              = 1 / (1 + exp(k * norm(c))) otherwise,
    norm(c) = (c - c_th) / (1 - c_th) - 1/2.
    High confidence -> low dispatch probability.
    """
    c = jnp.asarray(c, jnp.float32)
    norm = (c - c_th) / max(1.0 - c_th, 1e-6) - 0.5
    sig = 1.0 / (1.0 + jnp.exp(k * norm))
    return jnp.where(c <= c_th, 1.0, sig)


def p_imp(i, i_th: float, theta: float = -10.0):
    """Importance dispatch probability (three tiers).

    P_imp(i) = 0                               if i <= i_th/2
             = 1                               if i >  i_th
             = 1 / (1 + exp(theta * norm(i)))  otherwise,
    norm(i) = (i - i_th/2) / (i_th/2) - 1/2.
    High importance -> high dispatch probability.  theta < 0.
    """
    i = jnp.asarray(i, jnp.float32)
    lo = i_th / 2.0
    norm = (i - lo) / max(lo, 1e-9) - 0.5
    sig = 1.0 / (1.0 + jnp.exp(theta * norm))
    return jnp.where(i <= lo, 0.0, jnp.where(i > i_th, 1.0, sig))


@dataclass
class OffloadPolicy:
    """Runtime offloading decision; parameters come from offline profiling
    (core/profiling.py).  ``i_th`` is the budget knob (§6.3)."""

    c_th: float = 0.8
    i_th: float = 0.5
    k: float = 10.0
    theta: float = -10.0
    # "both" | "conf" | "imp" | "random" | "all" | "none" | "chunk_set"
    mode: str = "both"
    random_rate: float = 0.2  # for the "random" ablation baseline
    # explicit chunk ordinals to offload (the paper's Fig 5 oracle
    # measurement protocol: rank chunks offline by full-context
    # importance, offload the top n%)
    chunk_set: frozenset = frozenset()

    def dispatch_probability(self, mean_conf: float, mean_imp: float):
        pc = p_conf(mean_conf, self.c_th, self.k)
        pi = p_imp(mean_imp, self.i_th, self.theta)
        if self.mode == "both":
            return pc * pi
        if self.mode == "conf":
            return pc
        if self.mode == "imp":
            return pi
        if self.mode == "random":
            return jnp.asarray(self.random_rate, jnp.float32)
        if self.mode == "all":
            return jnp.asarray(1.0, jnp.float32)
        if self.mode == "none":
            return jnp.asarray(0.0, jnp.float32)
        raise ValueError(self.mode)

    def should_offload(self, rng: np.random.Generator, mean_conf, mean_imp,
                       *, seq_pos: int = 0, max_len: int = 0,
                       seq_exit_frac: float = 0.0,
                       chunk_index: int = -1) -> bool:
        """Sample the offload decision for one draft chunk.

        Sequence-wise early exit (§4.3): never offload past
        seq_exit_frac * max_len.
        """
        if self.mode == "chunk_set":
            return chunk_index in self.chunk_set
        if seq_exit_frac and max_len and seq_pos > seq_exit_frac * max_len:
            return False
        p = float(self.dispatch_probability(mean_conf, mean_imp))
        return bool(rng.random() < p)


def importance_from_percentile(importance_samples: np.ndarray, budget: float) -> float:
    """Map an offloading budget (fraction of chunks sent to cloud) to the
    i_th cutoff: the (1 - budget) percentile of the profiled importance
    distribution (§5)."""
    budget = float(np.clip(budget, 0.0, 1.0))
    if budget >= 1.0:
        return 0.0
    if budget <= 0.0:
        return float(np.max(importance_samples) * 2 + 1e9)
    return float(np.quantile(importance_samples, 1.0 - budget))
