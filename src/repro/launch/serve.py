"""Serving launcher: bring up the cloud engine + verification-aware
scheduler for a trained model pair and serve a batch of requests across
the chosen mode.

  PYTHONPATH=src:. python -m repro.launch.serve --mode synera \
      --budget 0.2 --requests 8 --max-new 48 --concurrency 4

Modes: synera | edge | cloud | hybrid | edgefm.

``--concurrency N`` (synera/hybrid) serves N device streams at once
through the SyneraServer event loop so cloud verify iterations pack
chunks from multiple slots; ``--concurrency 0`` means unbounded.
``--arrival-rate R`` draws Poisson request arrivals at R req/s on the
shared simulated clock (default: all streams arrive at admission).

``--replicas N`` (synera mode) serves the batch across N independent
cloud replicas behind a ``ReplicaRouter`` (serving/router.py); each
admission is placed by ``--route-policy`` (round-robin / least-loaded /
prefix-affinity) and token streams stay byte-identical to the
single-replica run.  Composes with ``--http``.

``--http`` instead brings up the OpenAI-compatible streaming gateway
(serving/gateway/, docs/serving_api.md) over the same engine + device
pair and serves real sockets until interrupted:

  PYTHONPATH=src:. python -m repro.launch.serve --http --port 8711 \
      --budget 0.2 --max-active 4 --queue-cap 8

The gateway runs on a wall clock (``RealClock``): requests are served
as fast as the host allows while the modeled schedule accumulates
shadow time for the modeled-vs-real cross-check on /metrics;
``--wall-pace`` instead sleeps through modeled costs so wall-clock
latencies track the modeled schedule.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="synera",
                    choices=["synera", "edge", "cloud", "hybrid", "edgefm"])
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=40)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--bandwidth-mbps", type=float, default=10.0)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="concurrent device sessions (0 = unbounded)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals in requests/s of simulated "
                         "time (0 = arrive at admission)")
    ap.add_argument("--attn-impl", default=None,
                    choices=["naive", "blocked", "pallas"],
                    help="cloud+device attention implementation; "
                         "'pallas' dispatches the repro/kernels TPU "
                         "kernels (decode_gqa / partial_prefill / "
                         "attn_importance; interpret mode off-TPU)")
    ap.add_argument("--verify-top-k", type=int, default=8,
                    help="top-k sampling support the fused verification "
                         "epilogue keeps device-side per row (the only "
                         "distribution state that crosses to the host)")
    ap.add_argument("--cache-impl", default=None,
                    choices=["dense", "paged"],
                    help="cloud KV cache layout: 'dense' reserves slots x "
                         "s_max up front; 'paged' backs slots with a "
                         "shared block pool + block tables so memory "
                         "scales with live sequence lengths and the "
                         "scheduler admits/preempts by free blocks")
    ap.add_argument("--block-size", type=int, default=None,
                    help="tokens per KV block (paged cache; must divide "
                         "the engine s_max)")
    ap.add_argument("--block-kv", type=int, default=None,
                    help="paged Pallas kernels only (--cache-impl paged "
                         "--attn-impl pallas): KV tokens fused into one "
                         "DMA per grid step; block-kv // block-size "
                         "consecutive block-table entries stream "
                         "together (unset: cfg.paged_block_kv)")
    ap.add_argument("--kv-splits", type=int, default=None,
                    help="paged Pallas kernels only: flash-decode "
                         "split-KV — partition the sequence axis into N "
                         "parallel splits whose online-softmax partials "
                         "are merged by a jnp epilogue; 1 is bit-"
                         "identical to the single-pass kernel (unset: "
                         "cfg.paged_kv_splits)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="total blocks in the paged pool (default: dense "
                         "capacity, slots x s_max / block-size; smaller "
                         "pools trade memory for preemptions)")
    ap.add_argument("--swap", action="store_true", default=None,
                    help="paged cache only: enable the host-memory KV "
                         "swap tier — preempted streams are gathered to "
                         "host RAM and restored later instead of "
                         "recompute-eviction when the modeled D2H+H2D "
                         "round trip beats the modeled re-prefill "
                         "(unset: cfg.kv_swap)")
    ap.add_argument("--host-swap-blocks", type=int, default=None,
                    help="host swap store capacity in KV blocks "
                         "(0 = unbounded; unset: cfg.host_swap_blocks); "
                         "victims that do not fit fall back to "
                         "recompute-eviction")
    ap.add_argument("--preempt-policy", default=None,
                    choices=["youngest", "most-blocks", "slo-aware"],
                    help="eviction victim selection when the paged pool "
                         "runs dry: youngest admitted stream (the "
                         "cfg.preempt_policy default), largest freeable "
                         "block holder, or the stream with the most "
                         "remaining TTFT/deadline slack")
    ap.add_argument("--share-prefix", action="store_true",
                    help="paged cache only: dedupe identical leading "
                         "full prompt blocks across streams (ref-counted "
                         "blocks, copy-on-write on divergent writes)")
    ap.add_argument("--retain-prefix", action="store_true", default=None,
                    help="paged cache only (implies --share-prefix): "
                         "keep released ref-0 prefix blocks on a "
                         "cached-free LRU so later sessions with the "
                         "same prompt prefix adopt them without "
                         "recompute (unset: cfg.retain_prefix)")
    ap.add_argument("--retain-blocks", type=int, default=None,
                    help="cached-free LRU capacity in KV blocks "
                         "(0 = unbounded; unset: cfg.retain_blocks)")
    ap.add_argument("--no-host-dedupe", action="store_false",
                    dest="host_dedupe", default=None,
                    help="disable the content-addressed host store "
                         "(with --swap + prefix sharing the host tier "
                         "dedupes identical swapped prefixes and new "
                         "sessions adopt matching host blocks; unset: "
                         "cfg.host_dedupe)")
    ap.add_argument("--shared-prefix-tokens", type=int, default=0,
                    help="prepend a common synthetic system prefix of N "
                         "tokens to every request (exercises prefix "
                         "sharing; task quality scores still use the "
                         "unmodified prompts, so treat them as a smoke "
                         "signal only)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="cloud replicas behind a ReplicaRouter (each an "
                         "independent engine + scheduler with its own "
                         "block pool / prefix index / swap tier); 1 = "
                         "no router (synera mode and --http only)")
    ap.add_argument("--route-policy", default="least-loaded",
                    choices=["round-robin", "least-loaded",
                             "prefix-affinity"],
                    help="fleet placement policy (--replicas > 1): "
                         "rotate, fewest live sessions / most free "
                         "blocks, or the replica whose prefix cache "
                         "already holds the longest prefix of the prompt")
    ap.add_argument("--replica-queue-cap", type=int, default=0,
                    help="live sessions per replica before it counts as "
                         "saturated; when ALL replicas are past it, new "
                         "streams degrade to device-only generation "
                         "instead of rejecting (0 = unbounded)")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--http", action="store_true",
                    help="serve the OpenAI-compatible streaming HTTP "
                         "gateway instead of a fixed batch (synera mode "
                         "only; runs until interrupted)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8711,
                    help="gateway port (0 = ephemeral)")
    ap.add_argument("--max-active", type=int, default=4,
                    help="gateway: concurrent streams in the serving "
                         "loop; beyond this, accepted requests queue")
    ap.add_argument("--queue-cap", type=int, default=8,
                    help="gateway: accepted-but-waiting requests beyond "
                         "--max-active before new ones get 429 + "
                         "Retry-After")
    ap.add_argument("--wall-pace", action="store_true",
                    help="gateway: sleep through modeled costs so "
                         "wall-clock latencies track the modeled "
                         "schedule (default: serve at host speed, "
                         "modeled time as a shadow cross-check)")
    ap.add_argument("--trace", action="store_true",
                    help="attach the unified tracer (serving/trace.py): "
                         "per-stream lifecycle spans + stall-time "
                         "attribution into /metrics and the summary; "
                         "token streams are byte-identical either way "
                         "(synera/hybrid modes; also --http, where the "
                         "gateway serves /v1/traces)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the Perfetto/Chrome trace-event JSON to "
                         "PATH after the run (implies --trace); load it "
                         "at ui.perfetto.dev")
    args = ap.parse_args()
    trace_on = args.trace or bool(args.trace_out)
    if args.concurrency < 0:
        ap.error("--concurrency must be >= 0 (0 = unbounded)")
    if args.http and args.mode != "synera":
        ap.error("--http serves the synera pipeline (--mode synera)")
    if trace_on and args.mode not in ("synera", "hybrid"):
        ap.error("--trace/--trace-out require --mode synera or hybrid "
                 "(only the SyneraServer event loop is instrumented)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1 and args.mode != "synera":
        ap.error("--replicas > 1 requires --mode synera (the fleet "
                 "router places synera sessions)")

    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY
    from repro.serving.link import LinkModel

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    if args.attn_impl is not None:
        slm_cfg = slm_cfg.replace(attn_impl=args.attn_impl)
    evalset = PC.eval_set(task, args.requests, seed=args.seed + 7)
    prompts = [p for p, _ in evalset]
    if args.shared_prefix_tokens > 0:
        rng = np.random.default_rng(args.seed + 29)
        common = [int(t) for t in rng.integers(
            1, slm_cfg.vocab - 1, args.shared_prefix_tokens)]
        prompts = [common + list(p) for p in prompts]
    link = LinkModel(bandwidth_mbps=args.bandwidth_mbps)
    if args.swap and args.cache_impl != "paged":
        ap.error("--swap requires --cache-impl paged")
    def mk_engine():
        return PC.make_engine(llm_cfg, llm_p, slots=args.slots,
                              attn_impl=args.attn_impl,
                              verify_top_k=args.verify_top_k,
                              cache_impl=args.cache_impl,
                              block_size=args.block_size,
                              pool_blocks=args.pool_blocks,
                              share_prefix=args.share_prefix,
                              swap=args.swap,
                              host_swap_blocks=args.host_swap_blocks,
                              retain_prefix=args.retain_prefix,
                              retain_blocks=args.retain_blocks,
                              host_dedupe=args.host_dedupe,
                              paged_block_kv=args.block_kv,
                              kv_splits=args.kv_splits)

    eng = mk_engine()
    # fleet mode: replica 0 reuses `eng` (also the profiling target);
    # the rest are independent engines with their own pools and caches
    engines = ([eng] + [mk_engine() for _ in range(args.replicas - 1)]
               if args.replicas > 1 else [eng])
    concurrency = None if args.concurrency == 0 else args.concurrency
    arrivals = None
    if args.arrival_rate > 0:
        rng = np.random.default_rng(args.seed + 13)
        gaps = rng.exponential(1e3 / args.arrival_rate, len(prompts))
        arrivals = np.cumsum(gaps).tolist()

    if args.share_prefix and args.cache_impl != "paged":
        print("warning: --share-prefix requires --cache-impl paged; "
              "ignored on the dense cache", file=sys.stderr)
    if args.mode not in ("synera", "hybrid") and (args.concurrency != 1
                                                  or arrivals is not None):
        print(f"warning: --concurrency/--arrival-rate only apply to "
              f"synera/hybrid; ignored for --mode {args.mode}",
              file=sys.stderr)

    if args.mode in ("synera", "hybrid", "edgefm"):
        dev0 = PC.make_device(slm_cfg, slm_p, link=link, gamma=args.gamma,
                              seed=args.seed)
        profile, _ = PC.profile_pair(dev0, eng, evalset, task)
        pol = OffloadPolicy(c_th=profile.c_th,
                            i_th=profile.i_th_for_budget(args.budget),
                            mode="both")
        dev = PC.make_device(slm_cfg, slm_p, policy=pol, link=link,
                             gamma=args.gamma, seed=args.seed,
                             alpha=profile.alpha)
    else:
        dev = PC.make_device(slm_cfg, slm_p, link=link, gamma=args.gamma,
                             seed=args.seed,
                             policy=OffloadPolicy(mode="none"))

    if args.http:
        from repro.serving.gateway import Gateway, GatewayConfig
        from repro.serving.link import RealClock
        from repro.serving.server import SyneraServer, build_fleet
        from repro.serving.trace import Tracer
        clock = RealClock(pace=args.wall_pace)
        tracer = Tracer(clock) if trace_on else None
        if args.replicas > 1:
            from repro.serving.router import ReplicaRouter
            servers = build_fleet(dev, engines, clock=clock,
                                  preempt_policy=args.preempt_policy,
                                  clamp_arrivals=not args.wall_pace,
                                  tracer=tracer)
            server = ReplicaRouter(servers, policy=args.route_policy,
                                   replica_queue_cap=args.replica_queue_cap)
        else:
            server = SyneraServer(dev, eng, clock=clock,
                                  preempt_policy=args.preempt_policy,
                                  clamp_arrivals=not args.wall_pace,
                                  tracer=tracer)
        Gateway(server, GatewayConfig(
            host=args.host, port=args.port,
            max_new_default=args.max_new,
            max_active=args.max_active,
            queue_cap=args.queue_cap)).run_forever()
        if args.trace_out and tracer is not None:
            print(f"trace written to {tracer.export(args.trace_out)}",
                  file=sys.stderr)
        return

    def run_synera_batch():
        if args.replicas > 1:
            return SY.run_synera_fleet(
                dev, engines, prompts, args.max_new,
                policy=args.route_policy,
                replica_queue_cap=args.replica_queue_cap,
                concurrency=concurrency, arrivals=arrivals,
                preempt_policy=args.preempt_policy, trace=trace_on)
        return SY.run_synera(dev, eng, prompts, args.max_new,
                             concurrency=concurrency, arrivals=arrivals,
                             preempt_policy=args.preempt_policy,
                             trace=trace_on)

    run = {
        "synera": run_synera_batch,
        "edge": lambda: SY.run_edge_centric(dev, prompts, args.max_new),
        "cloud": lambda: SY.run_cloud_centric(eng, prompts, args.max_new,
                                              link=link),
        "hybrid": lambda: SY.run_hybrid(dev, eng, prompts, args.max_new,
                                        concurrency=concurrency,
                                        arrivals=arrivals,
                                        preempt_policy=args.preempt_policy,
                                        trace=trace_on),
        "edgefm": lambda: SY.run_edgefm(dev, eng, prompts, args.max_new,
                                        link=link),
    }[args.mode]
    r = run()
    s = PC.score_outputs(task, evalset, r.outputs)
    # digest of all token streams: two runs served identically (e.g. a
    # roomy pool vs one forced to swap) must agree byte-for-byte
    sha = hashlib.sha256(
        json.dumps([[int(t) for t in o] for o in r.outputs]).encode()
    ).hexdigest()[:16]
    summary = dict(mode=args.mode, n=len(prompts), quality=s["quality"],
                   copy_acc=s["copy_acc"], tbt_ms=r.tbt_ms, cost=r.cost,
                   cloud_token_frac=r.cloud_token_frac, outputs_sha=sha)
    sched = r.extras.get("scheduler")
    if sched is not None:
        summary.update(
            concurrency=args.concurrency,
            verify_occupancy=sched["mean_verify_occupancy"],
            packed_tokens=sched["mean_packed_tokens"],
            iterations=sched["iterations"],
            # same ServerStats fields the gateway's /metrics exposes
            completed_streams=sched["completed_streams"],
            ttft_ms_p50=sched["ttft_ms_p50"],
            ttft_ms_p95=sched["ttft_ms_p95"],
            e2e_ms_p50=sched["e2e_ms_p50"],
            e2e_ms_p95=sched["e2e_ms_p95"])
        if sched.get("cache_impl") == "paged":
            summary.update(
                cache_impl="paged",
                block_size=sched["block_size"],
                blocks_used_peak=(f"{sched['peak_used_blocks']}"
                                  f"/{sched['n_blocks']}"),
                kv_bytes_peak=sched["kv_bytes_peak"],
                kv_cache_bytes=sched["kv_cache_bytes"],
                preemptions=sched["preemptions"],
                preempt_policy=sched["preempt_policy"],
                swap=sched["swap"],
                recompute_evictions=sched["recompute_evictions"],
                swap_evictions=sched["swap_evictions"],
                swapped_blocks=sched["swapped_blocks"],
                swap_out_bytes=sched["swap_out_bytes"],
                swap_in_bytes=sched["swap_in_bytes"],
                preempted_refed_tokens=sched["preempted_refed_tokens"],
                share_prefix=sched["share_prefix"],
                dedupe_hit_blocks=sched["dedupe_hit_blocks"],
                cow_copies=sched["cow_copies"],
                retain_prefix=sched["retain_prefix"],
                cached_free_blocks=sched["cached_free_blocks"],
                revived_blocks=sched["revived_blocks"],
                reclaimed_blocks=sched["reclaimed_blocks"],
                tail_shared_tokens=sched["tail_shared_tokens"],
                host_adopted_blocks=sched["host_adopted_blocks"],
                admission_swaps=sched["admission_swaps"],
                prefill_fed_tokens=sched["prefill_fed_tokens"])
        if sched.get("replicas", 1) > 1:
            summary.update(
                replicas=sched["replicas"],
                route_policy=sched["route_policy"],
                affinity_hits=sched["affinity_hits"],
                degraded_streams=sched["degraded_streams"],
                rerouted_sessions=sched["rerouted_sessions"],
                dead_replicas=sched["dead_replicas"])
        if sched.get("trace"):
            summary.update(
                trace=True,
                stall_wall_ms=sched["stall_wall_ms"],
                stall_device_ms=sched["stall_device_ms"],
                stall_cloud_ms=sched["stall_cloud_ms"],
                stall_link_ms=sched["stall_link_ms"],
                stall_queue_ms=sched["stall_queue_ms"],
                stall_batch_wait_ms=sched["stall_batch_wait_ms"],
                stall_swap_ms=sched["stall_swap_ms"],
                stall_preempted_ms=sched["stall_preempted_ms"],
                stall_other_ms=sched["stall_other_ms"])
    summary.update(
        engine_host_bytes=eng.bytes_to_host,
        engine_specializations=eng.compile_stats["n_specializations"])
    if args.trace_out:
        tracer = r.extras.get("tracer")
        if tracer is not None:
            summary["trace_out"] = tracer.export(args.trace_out)
    if args.json:
        print(json.dumps(summary))
    else:
        for k, v in summary.items():
            print(f"{k:18s} {v}")


if __name__ == "__main__":
    main()
