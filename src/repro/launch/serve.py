"""Serving launcher: bring up the cloud engine + verification-aware
scheduler for a trained model pair and serve a batch of requests across
the chosen mode.

  PYTHONPATH=src:. python -m repro.launch.serve --mode synera \
      --budget 0.2 --requests 8 --max-new 48

Modes: synera | edge | cloud | hybrid | edgefm.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="synera",
                    choices=["synera", "edge", "cloud", "hybrid", "edgefm"])
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=40)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--bandwidth-mbps", type=float, default=10.0)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    from benchmarks import paper_claims as PC
    from benchmarks.prepare import get_pair
    from repro.core.offload import OffloadPolicy
    from repro.serving import synergy as SY
    from repro.serving.link import LinkModel

    slm_cfg, slm_p, llm_cfg, llm_p, task = get_pair()
    evalset = PC.eval_set(task, args.requests, seed=args.seed + 7)
    prompts = [p for p, _ in evalset]
    link = LinkModel(bandwidth_mbps=args.bandwidth_mbps)
    eng = PC.make_engine(llm_cfg, llm_p, slots=args.slots)

    if args.mode in ("synera", "hybrid", "edgefm"):
        dev0 = PC.make_device(slm_cfg, slm_p, link=link, gamma=args.gamma,
                              seed=args.seed)
        profile, _ = PC.profile_pair(dev0, eng, evalset, task)
        pol = OffloadPolicy(c_th=profile.c_th,
                            i_th=profile.i_th_for_budget(args.budget),
                            mode="both")
        dev = PC.make_device(slm_cfg, slm_p, policy=pol, link=link,
                             gamma=args.gamma, seed=args.seed,
                             alpha=profile.alpha)
    else:
        dev = PC.make_device(slm_cfg, slm_p, link=link, gamma=args.gamma,
                             seed=args.seed,
                             policy=OffloadPolicy(mode="none"))

    run = {
        "synera": lambda: SY.run_synera(dev, eng, prompts, args.max_new),
        "edge": lambda: SY.run_edge_centric(dev, prompts, args.max_new),
        "cloud": lambda: SY.run_cloud_centric(eng, prompts, args.max_new,
                                              link=link),
        "hybrid": lambda: SY.run_hybrid(dev, eng, prompts, args.max_new),
        "edgefm": lambda: SY.run_edgefm(dev, eng, prompts, args.max_new,
                                        link=link),
    }[args.mode]
    r = run()
    s = PC.score_outputs(task, evalset, r.outputs)
    summary = dict(mode=args.mode, n=len(prompts), quality=s["quality"],
                   copy_acc=s["copy_acc"], tbt_ms=r.tbt_ms, cost=r.cost,
                   cloud_token_frac=r.cloud_token_frac)
    if args.json:
        print(json.dumps(summary))
    else:
        for k, v in summary.items():
            print(f"{k:18s} {v}")


if __name__ == "__main__":
    main()
