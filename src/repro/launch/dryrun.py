import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh and extract roofline inputs from the compiled
artifact.  MUST be run as a module: the two lines above execute before
any jax import (jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--shapes ...]

Outputs one JSON per combination under results/dryrun/.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import sharding as SH
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (cache_len_for, decode_window_for, input_specs,
                                params_specs)
from repro.models import model as M
from repro.models.steps import (make_decode_step, make_prefill_step,
                                make_train_step, make_verify_step)
from repro.optim.adamw import AdamW

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def build_step(cfg, shape_name: str, mesh):
    """Returns (fn, args_avals, in_shardings, out_shardings).  Also
    installs the logits sharding hint (models/shardctx.py)."""
    from repro.models import shardctx
    shp = INPUT_SHAPES[shape_name]
    p_avals = params_specs(cfg)
    mode = "train" if shp.kind == "train" else "serve"
    p_shard = SH.params_shardings(mesh, cfg, p_avals, mode=mode)
    specs = input_specs(cfg, shape_name)
    from repro.launch.mesh import batch_axes
    shardctx.set_hints(
        logits=SH.logits_sharding(mesh, cfg, shp.global_batch),
        mesh_batch_axes=(mesh, batch_axes(mesh)),
        moe_mesh=(mesh, batch_axes(mesh)) if cfg.n_experts else None)

    if shp.kind == "train":
        big = cfg.param_count() > 1e11
        opt = AdamW(state_dtype=jnp.bfloat16 if big else jnp.float32)
        # in-step gradient accumulation so activations fit HBM (§Perf it.7)
        n_par = cfg.param_count()
        micro = 16 if n_par > 1e11 else (8 if n_par > 5e9 else 1)
        o_avals = jax.eval_shape(opt.init, p_avals)
        # moments mirror the param shardings
        o_shard = type(o_avals)(
            step=SH.NamedSharding(mesh, SH.P()),
            mu=SH.params_shardings(mesh, cfg, o_avals.mu, mode="train"),
            nu=SH.params_shardings(mesh, cfg, o_avals.nu, mode="train"),
        )
        b_shard = SH.batch_shardings(mesh, specs["batch"])
        fn = make_train_step(cfg, opt, micro_batches=micro)
        args = (p_avals, o_avals, specs["batch"])
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, None)
        return fn, args, in_sh, out_sh

    c_avals = specs["cache"]
    c_shard = SH.cache_shardings(mesh, cfg, c_avals, shp.global_batch)

    if shp.kind == "prefill":
        fn0 = make_prefill_step(cfg)
        aux = specs["aux"]
        if aux:
            fn = lambda p, c, t, a: fn0(p, c, t, aux_inputs=a)
            args = (p_avals, c_avals, specs["tokens"], aux)
            in_sh = (p_shard, c_shard, SH.batch_shardings(mesh, specs["tokens"]),
                     SH.batch_shardings(mesh, aux))
        else:
            fn = lambda p, c, t: fn0(p, c, t)
            args = (p_avals, c_avals, specs["tokens"])
            in_sh = (p_shard, c_shard,
                     SH.batch_shardings(mesh, specs["tokens"]))
        return fn, args, in_sh, (None, c_shard)

    # decode / verify
    window = decode_window_for(cfg, shape_name)
    if shp.kind == "decode":
        dcfg = cfg.replace(attn_impl="naive")  # Tq=1: naive IS the decode
        fn = make_decode_step(dcfg, window=window)
    else:
        # §Perf iteration (verify hillclimb): a 32-token chunk over a 32k
        # cache wants the grouped (un-expanded) attention like decode —
        # the blocked path's head expansion reshards the cache across the
        # model axis (all-gather per verification iteration)
        vcfg = cfg.replace(attn_impl="naive")
        fn = make_verify_step(vcfg, window=window)
    args = (p_avals, c_avals, specs["tokens"], specs["positions"])
    in_sh = (p_shard, c_shard, SH.batch_shardings(mesh, specs["tokens"]),
             SH.batch_shardings(mesh, specs["positions"]))
    return fn, args, in_sh, (None, c_shard)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = "results/dryrun", cfg_override=None,
            tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cfg = cfg_override or get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "chips": n_chips, "tag": tag}
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh = build_step(cfg, shape_name, mesh)
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jf.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        ha = hlo_analyze(hlo)  # trip-count-aware (launch/hlo_analysis.py)

        flops = float(ha["flops"])
        bytes_acc = float(ha["bytes"])
        coll_bytes = float(ha["collective_bytes"])
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "flops_per_dev": flops,
            "bytes_per_dev": bytes_acc,
            "collective_bytes_per_dev": coll_bytes,
            "collective_by_kind": ha["collective_by_kind"],
            "trip_counts": ha["trip_counts"],
            "xla_cost_analysis": {
                "flops_body_once": float(cost.get("flops", 0.0)),
                "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
            },
            "hlo_bytes": len(hlo),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
        })
        # roofline terms (seconds, per chip)
        n_tokens = shp.global_batch * (1 if shp.kind == "decode"
                                       else (cfg.max_verify_chunk
                                             if shp.kind == "verify"
                                             else shp.seq_len))
        n_active = cfg.active_param_count()
        # train: 6ND (fwd 2ND + bwd 4ND); inference: 2ND
        model_flops = (6.0 if shp.kind == "train" else 2.0) * n_active * n_tokens
        rec["roofline"] = {
            "t_compute": flops / PEAK_FLOPS,
            "t_memory": bytes_acc / HBM_BW,
            "t_collective": coll_bytes / ICI_BW,
            "model_flops_per_dev": model_flops / n_chips,
            "useful_flops_ratio": (model_flops / n_chips) / max(flops, 1.0),
        }
        terms = {k: rec["roofline"][f"t_{k}"]
                 for k in ("compute", "memory", "collective")}
        rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    os.makedirs(out_dir, exist_ok=True)
    suffix = "_mp" if multi_pod else ""
    tag_s = f"_{tag}" if tag else ""
    fname = f"{out_dir}/{arch.replace('.', '_')}_{shape_name}{suffix}{tag_s}.json"
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = (args.shapes or
              ([args.shape] if args.shape else
               ["train_4k", "prefill_32k", "decode_32k", "long_500k"]))

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          out_dir=args.out, tag=args.tag)
            if rec["ok"]:
                r = rec["roofline"]
                print(f"OK   {arch:28s} {shape:12s} mesh={rec['mesh']:9s} "
                      f"compile={rec['compile_s']:6.1f}s "
                      f"t_comp={r['t_compute']:.2e} t_mem={r['t_memory']:.2e} "
                      f"t_coll={r['t_collective']:.2e} -> {r['bottleneck']}",
                      flush=True)
            else:
                n_fail += 1
                print(f"FAIL {arch:28s} {shape:12s}: {rec['error']}",
                      flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run failures")


if __name__ == "__main__":
    main()
