"""Sharding rules: parameter, optimizer-state, cache, and batch
PartitionSpecs for the production mesh.

Scheme (DESIGN.md §5):
* tensor parallel over "model": attention head projections, FFN hidden,
  MoE expert dim (expert parallel), vocab;
* FSDP over "data" in train mode: the non-model-sharded major dim of
  every large matrix (XLA all-gathers at use; halves-per-axis memory);
* batch over ("pod","data") when divisible; "pod" is pure data parallel.

Every rule checks divisibility and falls back to replication — GQA
architectures with few KV heads replicate K/V (standard under TP), and
serving KV caches shard the *sequence* dim over "model" (context
parallelism) because head counts don't cover a 16-way axis while 32k+
caches dominate HBM.

``serve`` mode drops FSDP on params (pure TP + replication) — the
paper-beyond optimization for decode (EXPERIMENTS.md §Perf) — except MoE
expert banks, which stay sharded over ("data" x "model") to fit.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, batch_axes


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0 and n >= size


def _key_name(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "idx", entry)))


def param_spec(path, leaf, mesh, cfg, *, mode: str = "train",
               fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf (path-based rules)."""
    msz = axis_size(mesh, "model")
    dsz = axis_size(mesh, "data")
    names = [_key_name(p) for p in path]
    name = names[-1]
    shape = leaf.shape
    nd = len(shape)
    spec = [None] * nd
    want_fsdp = fsdp and mode == "train"

    def set_trailing(model_dim_offset, data_dim_offset):
        """model_dim_offset/data_dim_offset: negative offsets from the end."""
        mi = nd + model_dim_offset
        di = nd + data_dim_offset
        if mi >= 0 and _div(shape[mi], msz):
            spec[mi] = "model"
        if want_fsdp and di >= 0 and spec[di] is None and _div(shape[di], dsz):
            spec[di] = "data"

    is_moe_expert = ("moe" in names and "shared" not in names
                     and name in ("w_gate", "w_up", "w_down"))

    if name in ("wq", "wk", "wv"):
        # (.., d, heads*hd): shard output heads over model (replicate K/V
        # when n_kv*hd not divisible — the _div check handles it)
        set_trailing(-1, -2)
    elif name == "wo":
        # (.., heads*hd, d): shard the contraction (head) dim over model
        set_trailing(-2, -1)
    elif is_moe_expert:
        # (.., E, d, dff) / (.., E, dff, d).  Expert-parallel layout for
        # the shard_map EP region (layers.moe_ffn_ep, §Perf iteration 3):
        # E over "model" (each model rank owns its experts), d over
        # "data" (FSDP: all-gathered per layer inside the region).  Memory
        # per device: two 16-way shards — 400B Maverick fits at 3 GB/dev.
        if _div(shape[-3], msz):
            spec[-3] = "model"         # experts
        di = nd - 1 if name == "w_down" else nd - 2
        if _div(shape[di], dsz):
            spec[di] = "data"          # expert d (FSDP-gathered in-region)
    elif name in ("w_gate", "w_up"):
        set_trailing(-1, -2)   # (.., d, dff): dff over model
    elif name == "w_down":
        set_trailing(-2, -1)   # (.., dff, d): dff over model
    elif name == "router":
        if want_fsdp and _div(shape[-2], dsz):
            spec[-2] = "data"
    elif name == "embed":
        if _div(shape[0], msz):
            spec[0] = "model"
        if want_fsdp and _div(shape[1], dsz):
            spec[1] = "data"
    elif name == "unembed":
        set_trailing(-1, -2)   # (d, V): vocab over model
    elif name == "vision_proj":
        set_trailing(-1, -2)
    elif name == "in_proj":
        set_trailing(-1, -2)   # mamba (d, 2di+2N+H)
    elif name == "out_proj":
        set_trailing(-2, -1)   # mamba (di, d)
    elif name == "conv_w":
        if _div(shape[-1], msz):
            spec[-1] = "model"
    elif name in ("bq", "bk", "bv"):
        if _div(shape[-1], msz):
            spec[-1] = "model"
    # norms, gates, A_log, D, dt_bias, conv_b, gate_norm: replicated
    return P(*spec)


def params_shardings(mesh, cfg, params_avals, *, mode: str = "train",
                     fsdp: bool = True):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_avals)
    specs = [NamedSharding(mesh, param_spec(p, l, mesh, cfg, mode=mode,
                                            fsdp=fsdp))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Cache shardings (serving)
# ---------------------------------------------------------------------------

_SEQ_SHARD_THRESHOLD = 8 << 30  # bytes/device above which S must shard


def cache_spec(path, leaf, mesh, cfg, batch: int, *, total_bytes: int = 0) -> P:
    """KV/SSM cache leaves.

    Layouts (leading layer/round axes never sharded):
      k/v:   (..., B, S, nkv, hd)  -> B over data; S over model ONLY when
                                      the batch-sharded cache exceeds the
                                      per-device HBM budget.
      pos:   (..., B, S)           -> follows k/v
      state: (..., B, H, Pd, N)    -> B over data, H over model
      conv:  (..., B, W-1, C)      -> B over data, C over model

    §Perf iteration (decode hillclimb): scattering one decode token into
    an S-sharded circular cache makes XLA all-gather the WHOLE cache
    every step (17 GB/step for a 1B model — 3x the compute+memory terms).
    Batch-only sharding keeps the scatter local; context(S)-parallelism
    is reserved for caches that genuinely cannot fit (110B-class 32k
    decode), where the gather is the price of fitting.
    """
    dsz = axis_size(mesh, "data")
    msz = axis_size(mesh, "model")
    name = _key_name(path[-1])
    shape = leaf.shape
    nd = len(shape)
    spec = [None] * nd
    if name in ("k", "v"):
        bi, si = nd - 4, nd - 3
    elif name == "pos":
        bi, si = nd - 2, nd - 1
    elif name == "state":
        bi, si = nd - 4, nd - 3
    elif name == "conv":
        bi, si = nd - 3, nd - 1
    else:
        return P(*spec)
    b_sharded = _div(shape[bi], dsz)
    if b_sharded:
        spec[bi] = "data"
    if name in ("k", "v", "pos"):
        per_dev = total_bytes // (dsz if b_sharded else 1)
        if per_dev > _SEQ_SHARD_THRESHOLD and _div(shape[si], msz):
            spec[si] = "model"
    else:
        if _div(shape[si], msz):
            spec[si] = "model"
    return P(*spec)


def cache_shardings(mesh, cfg, cache_avals, batch: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_avals)
    total_bytes = sum(l.size * l.dtype.itemsize for _, l in flat)
    specs = [NamedSharding(mesh, cache_spec(p, l, mesh, cfg, batch,
                                            total_bytes=total_bytes))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / activation shardings
# ---------------------------------------------------------------------------

def batch_spec(mesh, batch: int, nd: int) -> P:
    """Shard the leading batch dim over as many data axes as divide it."""
    axes = [a for a in batch_axes(mesh)]
    total = 1
    for a in axes:
        total *= axis_size(mesh, a)
    spec = [None] * nd
    if batch % total == 0 and batch >= total:
        spec[0] = tuple(axes) if len(axes) > 1 else axes[0]
    elif batch % axis_size(mesh, "data") == 0 and batch >= axis_size(mesh, "data"):
        spec[0] = "data"
    return P(*spec)


def logits_sharding(mesh, cfg, batch: int):
    """(B, T, V) logits: batch over data axes, vocab over model.  Installed
    as a with_sharding_constraint hint — without it XLA replicates the
    unembed matmul across the model axis (measured 4.5x FLOP inflation)."""
    msz = axis_size(mesh, "model")
    bspec = batch_spec(mesh, batch, 3)
    vdim = "model" if _div(cfg.vocab, msz) else None
    return NamedSharding(mesh, P(bspec[0], None, vdim))


def batch_shardings(mesh, tree_avals):
    def one(leaf):
        return NamedSharding(mesh, batch_spec(mesh, leaf.shape[0],
                                              len(leaf.shape)))
    return jax.tree.map(one, tree_avals)
