"""Training launcher: trains a model on the synthetic corpus.

Two uses:
  * CPU-real: train the tiny SLM/LLM pair for the end-to-end Synera
    experiments (examples/, benchmarks/) — real gradients, real tokens.
  * Production config: builds the same train_step under the production
    mesh shardings (the dry-run path exercises every assigned arch).

Usage:
  PYTHONPATH=src python -m repro.launch.train --model tiny-slm --steps 300
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.synera_pair import tiny_pair
from repro.checkpoint import io as ckpt
from repro.data.synthetic import SyntheticTask, TaskSpec, batches
from repro.models import model as M
from repro.models.steps import make_train_step
from repro.optim.adamw import AdamW, cosine_schedule


def get_tiny(name: str, vocab: int):
    slm, llm = tiny_pair(vocab=vocab)
    return {"tiny-slm": slm, "tiny-llm": llm}[name]


def train(cfg, *, steps: int = 300, batch_size: int = 16, seq_len: int = 128,
          lr: float = 3e-3, seed: int = 0, corpus=None, log_every: int = 50,
          ckpt_path: str | None = None):
    task = SyntheticTask(TaskSpec(vocab=cfg.vocab))
    if corpus is None:
        corpus, _ = task.corpus(n_sequences=64, length=2048, seed=seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, schedule=cosine_schedule(lr, warmup=20, total=steps),
                weight_decay=0.01)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    it = batches(corpus, batch_size, seq_len,
                 rng=np.random.default_rng(seed + 1))
    t0 = time.time()
    losses = []
    for step in range(steps):
        batch = {"tokens": jnp.asarray(next(it))}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if log_every and (step + 1) % log_every == 0:
            print(f"  step {step+1:5d} loss {np.mean(losses[-log_every:]):.4f} "
                  f"({(time.time()-t0)/ (step+1)*1e3:.0f} ms/step)", flush=True)
    if ckpt_path:
        ckpt.save(ckpt_path, params)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tiny-slm")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cfg = get_tiny(args.model, args.vocab)
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.param_count()/1e6:.1f}M params)")
    train(cfg, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
          lr=args.lr, ckpt_path=args.out or f"results/ckpt/{cfg.name}.npz")


if __name__ == "__main__":
    main()
