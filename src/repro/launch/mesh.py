"""Production mesh construction.

Defined as functions (not module-level constants) so importing this
module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else (tests, benches) sees the real single CPU device.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: Auto is the only (implicit) behavior
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod: 16x16 = 256 chips; multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1, data: int = 1):
    """Small mesh over forced host devices (tests use 8)."""
    return _make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes over which the global batch is sharded."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
