"""ShapeDtypeStruct stand-ins for every model input: the dry-run lowers
against these (weak-type-correct, shardable, zero device allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.models import model as M


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def aux_specs(cfg: ModelConfig, batch: int) -> dict:
    aux = {}
    if cfg.family == "vlm":
        aux["image_embeds"] = _sds((batch, cfg.n_image_tokens,
                                    cfg.vision_dim), cfg.dtype)
    if cfg.family == "audio":
        aux["audio_frames"] = _sds((batch, cfg.n_audio_frames, cfg.d_model),
                                   cfg.dtype)
    return aux


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda k: M.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, s_max))


def cache_len_for(cfg: ModelConfig, shape_name: str) -> int:
    """Attention cache buffer length for a decode shape: the full context
    for decode_32k, the sliding window for long_500k (sub-quadratic path
    for attention archs; SSM archs carry O(1) state regardless)."""
    shp = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k":
        return cfg.sliding_window
    return shp.seq_len


def decode_window_for(cfg: ModelConfig, shape_name: str) -> int:
    return cfg.sliding_window if shape_name == "long_500k" else 0


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Everything a step function needs, as ShapeDtypeStructs.

    train:   {batch: {tokens, [aux]}}
    prefill: {cache, tokens, [aux]}
    decode:  {cache, token (B,1), pos (B,1)}
    verify:  {cache, tokens (B,C), pos (B,C)}  (the paper's partial prefill)
    """
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    if shp.kind == "train":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        batch.update(aux_specs(cfg, B))
        return {"batch": batch}
    if shp.kind == "prefill":
        return {
            "cache": cache_specs(cfg, B, S),
            "tokens": _sds((B, S), jnp.int32),
            "aux": aux_specs(cfg, B),
        }
    if shp.kind == "decode":
        s_max = cache_len_for(cfg, shape_name)
        return {
            "cache": cache_specs(cfg, B, s_max),
            "tokens": _sds((B, 1), jnp.int32),
            "positions": _sds((B, 1), jnp.int32),
        }
    if shp.kind == "verify":
        C = cfg.max_verify_chunk
        return {
            "cache": cache_specs(cfg, B, S),
            "tokens": _sds((B, C), jnp.int32),
            "positions": _sds((B, C), jnp.int32),
        }
    raise ValueError(shp.kind)
