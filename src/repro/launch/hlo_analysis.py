"""Trip-count-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, but
our layer stacks are ``lax.scan``s — a 94-layer model's per-layer cost
would be undercounted ~94x.  This module walks the post-SPMD HLO text,
builds the computation call graph (while bodies with
``known_trip_count``, calls, conditionals), and accumulates

  * dot/convolution FLOPs     (2 x prod(result dims) x prod(contract dims),
                               operand shapes resolved from each
                               computation's local def table)
  * bytes accessed            (result + operand array bytes per op — the
                               fused-kernel HBM-traffic approximation:
                               fusion subcomputations are not walked, the
                               fusion op's operands/result at the callsite
                               are the actual traffic)
  * collective result bytes   (all-gather / all-reduce / reduce-scatter /
                               all-to-all / collective-permute)

each multiplied by the product of enclosing trip counts.  This is the
measurement backbone for EXPERIMENTS.md §Roofline.  The HLO here is the
post-SPMD per-device module, so all numbers are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT )?%?([\w\.\-]+) = (.+?) ([\w\-]+)\(")
_HDR_RE = re.compile(r"^(ENTRY )?%?([\w\.\-]+) \(")
_TRIP_RE = re.compile(r'known_trip_count\\?":\s*{\\?"n\\?":\\?"(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations={([^}]*)}")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims={([\d,]*)}")

_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "get-dimension-size",
             "while", "conditional", "call", "custom-call", "iota",
             "rng-bit-generator", "opt-barrier"}


def _array_bytes(text: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> list[int]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class OpStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    n_ops: int = 0
    unresolved_dots: int = 0


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    lines: list = field(default_factory=list)
    calls: list = field(default_factory=list)     # (callee, multiplier)
    stats: OpStats = field(default_factory=OpStats)


def _parse_computations(hlo: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        if raw and not raw.startswith(" ") and raw.rstrip().endswith("{") \
                and "->" in raw:
            m = _HDR_RE.match(raw)
            if m:
                cur = Computation(m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                continue
        if cur is None:
            continue
        s = raw.strip()
        if s == "}":
            cur = None
        elif s:
            cur.lines.append(s)
    return comps, entry


def _operand_names(rhs: str, op: str) -> list[str]:
    seg = rhs.split(op + "(", 1)
    if len(seg) < 2:
        return []
    inner = seg[1].split(")", 1)[0]
    return re.findall(r"%([\w\.\-]+)", inner)


def _analyze_comp(c: Computation):
    types: dict[str, str] = {}
    # pass 1: local def table (params via their GTE/parameter lines)
    parsed = []
    for s in c.lines:
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, type_str, op = m.groups()
        types[name] = type_str
        parsed.append((name, type_str, op, s))
        if op == "parameter" or s.split(" = ", 1)[1].startswith(type_str + " parameter"):
            pass
    # parameter lines look like: %param.2 = f32[4,64]{1,0} parameter(0)
    for name, type_str, op, s in parsed:
        st = c.stats
        st.n_ops += 1
        base = op[:-6] if op.endswith("-start") else op
        base = base[:-5] if base.endswith("-done") else base
        if " while(" in s or " conditional(" in s or re.search(
                r" call\(", s) or "async-call" in s:
            c.calls.extend(_find_calls(s))
        if base in _FREE_OPS:
            continue
        operands = _operand_names(s, op)
        op_bytes = _array_bytes(type_str)
        for o in operands:
            if o in types:
                op_bytes += _array_bytes(types[o])
        if base in _COLLECTIVES:
            b = _array_bytes(type_str)
            st.collective_bytes += b
            st.collective_by_kind[base] = st.collective_by_kind.get(base, 0) + b
            st.bytes += op_bytes
            continue
        st.bytes += op_bytes
        if base == "dot":
            res = _first_shape(type_str)
            cm = _DOT_CDIMS.search(s)
            lhs_t = types.get(operands[0]) if operands else None
            if cm is not None and lhs_t:
                lshape = _first_shape(lhs_t)
                k = 1
                for cd in (int(d) for d in cm.group(1).split(",") if d):
                    if cd < len(lshape):
                        k *= lshape[cd]
                n = 1
                for d in res:
                    n *= d
                st.flops += 2.0 * n * k
            else:
                st.unresolved_dots += 1
        elif base == "convolution":
            res = _first_shape(type_str)
            n = 1
            for d in res:
                n *= d
            k = 1
            if len(operands) >= 2 and operands[1] in types:
                for d in _first_shape(types[operands[1]]):
                    k *= d
            st.flops += 2.0 * n * max(k, 1)


def _find_calls(s: str) -> list[tuple[str, float]]:
    out = []
    if " while(" in s:
        trip = 1.0
        tm = _TRIP_RE.search(s)
        if tm:
            trip = float(tm.group(1))
        bm = _BODY_RE.search(s)
        if bm:
            out.append((bm.group(1), trip))
        cm = _COND_RE.search(s)
        if cm:
            out.append((cm.group(1), trip + 1))
    elif " conditional(" in s:
        bm = _BRANCH_RE.search(s)
        if bm:
            for name in bm.group(1).split(","):
                out.append((name.strip().lstrip("%"), 1.0))
    else:
        cm = _CALL_RE.search(s)
        if cm and (re.search(r" call\(", s) or "async-call" in s):
            out.append((cm.group(1), 1.0))
    return out


def analyze(hlo: str) -> dict:
    comps, entry = _parse_computations(hlo)
    for c in comps.values():
        _analyze_comp(c)

    total = OpStats()
    trip_counts = []

    def walk(name: str, mult: float, depth: int = 0):
        c = comps.get(name)
        if c is None or depth > 64:
            return
        total.flops += c.stats.flops * mult
        total.bytes += c.stats.bytes * mult
        total.collective_bytes += c.stats.collective_bytes * mult
        total.unresolved_dots += c.stats.unresolved_dots
        for k, v in c.stats.collective_by_kind.items():
            total.collective_by_kind[k] = (
                total.collective_by_kind.get(k, 0) + v * mult)
        for callee, m in c.calls:
            if m > 1.0:
                trip_counts.append(int(m))
            walk(callee, mult * m, depth + 1)

    if entry:
        walk(entry, 1.0)
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "collective_bytes": total.collective_bytes,
        "collective_by_kind": dict(total.collective_by_kind),
        "trip_counts": sorted(set(trip_counts), reverse=True)[:16],
        "n_computations": len(comps),
        "unresolved_dots": total.unresolved_dots,
    }
