"""Model assembly: parameter/cache init and family-dispatched forward.

Six families (DESIGN.md §3): dense, moe, vlm, audio, ssm, hybrid.
All layer stacks run under ``lax.scan`` over stacked parameters, so the
HLO is O(1) in depth.  Caches are pytrees whose leaves carry a leading
layer/round axis aligned with the scan.

Conventions
-----------
* ``cache`` pytrees use ``{}`` (leaf-free dict) to mean "no cache" inside
  scans; ``_none`` converts back to None at the layer level.
* ``positions`` is always (B, T) int32 absolute positions.
* forward(...) returns ``(logits, new_cache, importance, aux_loss)``.
* ``window`` > 0 enables sliding-window attention over a circular cache
  (the long_500k path for attention archs).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import shardctx


def _none(c):
    return c if c else None


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg, *, ffn: str = "mlp", d_ff=None, mha=False):
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    nh = cfg.n_heads
    nkv = nh if mha else cfg.n_kv_heads
    p = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attn(k1, cfg.d_model, nh, nkv, cfg.head_dim,
                            bias=cfg.qkv_bias, dtype=dt),
    }
    dff = d_ff if d_ff is not None else cfg.d_ff
    if ffn == "mlp":
        p["mlp"] = L.init_mlp(k2, cfg.d_model, dff, dtype=dt)
    else:
        p["moe"] = L.init_moe(k2, cfg.d_model, dff, cfg.n_experts,
                              n_shared=cfg.n_shared_experts, dtype=dt)
    return p


def _init_cross_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, bias=cfg.qkv_bias, dtype=dt),
        "gate_attn": jnp.zeros((1,), dt),
        "gate_ffn": jnp.zeros((1,), dt),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype=dt),
    }


def _init_encdec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dtype(cfg)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "lnx": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "self_attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, dtype=dt),
        "cross_attn": L.init_attn(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, dtype=dt),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype=dt),
    }


def _stacked(init_fn, key, n, *a, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *a, **kw))(keys)


def init_params(cfg, key):
    dt = _dtype(cfg)
    ke, ku, kl, kx = jax.random.split(key, 4)
    V, d = cfg.vocab, cfg.d_model
    params = {
        "embed": (jax.random.normal(ke, (V, d)) * 0.02).astype(dt),
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(ku, (d, V)) / math.sqrt(d)).astype(dt)

    fam = cfg.family
    if fam == "dense":
        params["layers"] = _stacked(_init_dense_layer, kl, cfg.n_layers, cfg)
    elif fam == "moe":
        if cfg.moe_every == 1:
            params["layers"] = _stacked(_init_dense_layer, kl, cfg.n_layers,
                                        cfg, ffn="moe")
        else:
            n_rounds = cfg.n_layers // cfg.moe_every
            k1, k2 = jax.random.split(kl)
            params["dense_layers"] = _stacked(
                _init_dense_layer, k1, n_rounds, cfg, ffn="mlp",
                d_ff=cfg.d_ff_dense)
            params["moe_layers"] = _stacked(
                _init_dense_layer, k2, n_rounds, cfg, ffn="moe")
    elif fam == "vlm":
        n_rounds = cfg.n_layers // cfg.cross_attn_every
        self_per = cfg.cross_attn_every - 1
        k1, k2 = jax.random.split(kl)
        keys = jax.random.split(k1, n_rounds)
        params["self_layers"] = jax.vmap(
            lambda k: _stacked(_init_dense_layer, k, self_per, cfg))(keys)
        params["cross_layers"] = _stacked(_init_cross_layer, k2, n_rounds, cfg)
        params["vision_proj"] = (
            jax.random.normal(kx, (cfg.vision_dim, d)) / math.sqrt(cfg.vision_dim)
        ).astype(dt)
    elif fam == "audio":
        k1, k2 = jax.random.split(kl)
        params["enc_layers"] = _stacked(_init_dense_layer, k1,
                                        cfg.n_encoder_layers, cfg)
        params["enc_norm"] = jnp.ones((d,), dt)
        params["dec_layers"] = _stacked(_init_encdec_layer, k2, cfg.n_layers, cfg)
    elif fam == "ssm":
        params["layers"] = _stacked(L.init_mamba, kl, cfg.n_layers, cfg, dtype=dt)
    elif fam == "hybrid":
        n_rounds = cfg.n_layers // cfg.attn_every
        keys = jax.random.split(kl, n_rounds)
        params["mamba_rounds"] = jax.vmap(
            lambda k: _stacked(L.init_mamba, k, cfg.attn_every, cfg, dtype=dt))(keys)
        params["shared_attn"] = _init_dense_layer(kx, cfg, mha=True)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, s_max: int, *, cache_impl: str | None = None,
               block_size: int | None = None, pool_blocks: int | None = None):
    """Serving cache. ``s_max`` is the attention buffer length (the
    sliding window size for long-context decode).

    ``cache_impl`` (default ``cfg.cache_impl``) selects the layout:

    * ``"dense"`` -- one contiguous (batch, s_max) buffer per slot; memory
      cost is ``batch * s_max`` regardless of actual sequence lengths.
    * ``"paged"`` -- a shared pool of ``pool_blocks`` fixed-size blocks
      (``block_size`` tokens each, default ``cfg.kv_block_size``) plus a
      per-slot ``block_tables`` (batch, s_max/block_size) int32 map; -1
      marks an unmapped table entry.  Unmapped/invalid entries read as
      pos=-1 (masked) so attention over the gathered view is bit-identical
      to the dense path.  ``pool_blocks`` defaults to dense capacity
      (``batch * s_max / block_size``); serving engines pass a smaller
      pool and page slots on demand (serving/engine.BlockAllocator).
      Only kv_stack families (dense, moe) support paging — SSM/conv
      states are fixed-size per slot and have nothing to page.
    """
    dt = _dtype(cfg)
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    impl = cache_impl or getattr(cfg, "cache_impl", "dense")

    if impl == "paged":
        bs = block_size or cfg.kv_block_size
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"cache_impl='paged' supports dense/moe families (pure "
                f"kv_stack caches); got {cfg.family!r}")
        if s_max % bs:
            raise ValueError(f"s_max {s_max} not divisible by "
                             f"kv block size {bs}")
        max_bps = s_max // bs                 # blocks per slot
        nb = pool_blocks if pool_blocks is not None else batch * max_bps

        def kv_stack(n):
            return {
                "k": jnp.zeros((n, nb, bs, nkv, hd), dt),
                "v": jnp.zeros((n, nb, bs, nkv, hd), dt),
                "pos": jnp.full((n, nb, bs), -1, jnp.int32),
                # replicated along the layer axis so every cache leaf
                # aligns with the lax.scan over stacked layers
                "block_tables": jnp.full((n, batch, max_bps), -1,
                                         jnp.int32),
            }

        if cfg.family == "dense" or cfg.moe_every == 1:
            return {"layers": kv_stack(cfg.n_layers)}
        n_rounds = cfg.n_layers // cfg.moe_every
        return {"dense": kv_stack(n_rounds), "moe": kv_stack(n_rounds)}

    def kv_stack(n):
        return {
            "k": jnp.zeros((n, batch, s_max, nkv, hd), dt),
            "v": jnp.zeros((n, batch, s_max, nkv, hd), dt),
            "pos": jnp.full((n, batch, s_max), -1, jnp.int32),
        }

    fam = cfg.family
    if fam == "dense":
        return {"layers": kv_stack(cfg.n_layers)}
    if fam == "moe":
        if cfg.moe_every == 1:
            return {"layers": kv_stack(cfg.n_layers)}
        n_rounds = cfg.n_layers // cfg.moe_every
        return {"dense": kv_stack(n_rounds), "moe": kv_stack(n_rounds)}
    if fam == "vlm":
        n_rounds = cfg.n_layers // cfg.cross_attn_every
        self_per = cfg.cross_attn_every - 1
        sc = kv_stack(n_rounds * self_per)
        sc = jax.tree.map(
            lambda x: x.reshape((n_rounds, self_per) + x.shape[1:]), sc)
        cross = {
            "k": jnp.zeros((n_rounds, batch, cfg.n_image_tokens, nkv, hd), dt),
            "v": jnp.zeros((n_rounds, batch, cfg.n_image_tokens, nkv, hd), dt),
        }
        return {"self": sc, "cross": cross}
    if fam == "audio":
        cross = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.n_audio_frames, nkv, hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.n_audio_frames, nkv, hd), dt),
        }
        return {"self": kv_stack(cfg.n_layers), "cross": cross}
    if fam == "ssm":
        def one(_):
            return L.init_mamba_cache(cfg, batch, dt)
        return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}
    if fam == "hybrid":
        n_rounds = cfg.n_layers // cfg.attn_every
        def one(_):
            return L.init_mamba_cache(cfg, batch, dt)
        mam = jax.vmap(one)(jnp.arange(cfg.n_layers))
        mam = jax.tree.map(
            lambda x: x.reshape((n_rounds, cfg.attn_every) + x.shape[1:]), mam)
        return {"mamba": mam, "attn": kv_stack(n_rounds)}
    raise ValueError(fam)


def copy_cache_blocks(cache, src, dst):
    """Copy-on-write fork over a whole paged cache: clone pool blocks
    ``src[i] -> dst[i]`` in every paged kv stack (k/v/pos move together;
    block tables are untouched — the allocator already rewrote the
    writer's entry).  One jitted, donated dispatch in the engine."""

    def walk(c):
        if "block_tables" in c:
            return L.cache_copy_blocks(c, src, dst)
        return {k: walk(v) if isinstance(v, dict) else v
                for k, v in c.items()}

    return walk(cache)


def copy_cache_block_rows(cache, src, dst, rows):
    """Partial-block tail copy over a whole paged cache: clone the first
    ``rows[i]`` token rows of pool block ``src[i]`` into ``dst[i]`` in
    every paged kv stack (the sub-block analogue of
    :func:`copy_cache_blocks`).  One jitted, donated dispatch in the
    engine."""

    def walk(c):
        if "block_tables" in c:
            return L.cache_copy_block_rows(c, src, dst, rows)
        return {k: walk(v) if isinstance(v, dict) else v
                for k, v in c.items()}

    return walk(cache)


def peek_cache_blocks(cache, blocks):
    """Read-only gather over a whole paged cache: pull pool blocks
    ``blocks[i]`` (k/v/pos) out of every paged kv stack WITHOUT
    invalidating them.  Returns the same payload pytree shape as
    :func:`swap_out_blocks` (so :func:`swap_in_blocks` can restore it),
    but the cache is untouched — jitted without donation.  The
    content-addressed host tier demotes still-valid blocks with this."""

    def walk(c):
        if "block_tables" in c:
            return L.cache_peek_blocks(c, blocks)
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = walk(v)
        return out

    return walk(cache)


def swap_out_blocks(cache, blocks):
    """Host-swap gather over a whole paged cache: pull pool blocks
    ``blocks[i]`` (k/v/pos) out of every paged kv stack and invalidate
    their pool positions, in one jitted dispatch (the engine donates the
    cache).  Returns ``(payload, new_cache)``; ``payload`` mirrors the
    cache structure but holds only the gathered stacks — the swap
    manager moves it to host memory and later feeds it back through
    :func:`swap_in_blocks`."""

    def walk(c):
        if "block_tables" in c:
            return L.cache_gather_blocks(c, blocks)
        pays, news = {}, {}
        for k, v in c.items():
            if isinstance(v, dict):
                pays[k], news[k] = walk(v)
            else:
                news[k] = v
        return pays, news

    return walk(cache)


def swap_in_blocks(cache, blocks, payload):
    """Host-swap scatter over a whole paged cache: restore a payload
    gathered by :func:`swap_out_blocks` into (freshly allocated) pool
    blocks ``blocks[i]`` across every paged kv stack, one jitted,
    donated dispatch.  The restored blocks are bit-identical to the
    swapped-out content."""

    def walk(c, p):
        if "block_tables" in c:
            return L.cache_scatter_blocks(c, blocks, p)
        return {k: walk(v, p[k]) if isinstance(v, dict) else v
                for k, v in c.items()}

    return walk(cache, payload)


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _layer(cfg, p, h, pos, cache, *, window=0, ret_imp=False, ffn="mlp",
           mha=False):
    a_in = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    a, nc, imp = L.attn_block(
        p["attn"], a_in, pos, cfg, cache, window=window,
        return_importance=ret_imp,
        n_heads=cfg.n_heads, n_kv=cfg.n_heads if mha else cfg.n_kv_heads)
    h = h + a
    f_in = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    if ffn == "mlp":
        f, aux = L.mlp(p["mlp"], f_in), jnp.zeros((), jnp.float32)
    else:
        # expert-parallel shard_map path when a mesh hint is installed;
        # single-host auto path otherwise (layers.moe_ffn_ep falls back)
        f, aux = L.moe_ffn_ep(p["moe"], f_in, top_k=cfg.top_k)
    return h + f, nc, imp, aux


def _cross_attn(cfg, p, x, kv_src=None, cross_cache=None):
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B, T, _ = x.shape
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, T, nh, hd)
    if kv_src is not None:
        S = kv_src.shape[1]
        k = kv_src @ p["wk"]
        v = kv_src @ p["wv"]
        if "bk" in p:
            k = k + p["bk"]
            v = v + p["bv"]
        k = k.reshape(B, S, nkv, hd)
        v = v.reshape(B, S, nkv, hd)
        new_cache = None
        if cross_cache is not None:
            new_cache = {"k": k.astype(cross_cache["k"].dtype),
                         "v": v.astype(cross_cache["v"].dtype)}
    else:
        k, v = cross_cache["k"], cross_cache["v"]
        new_cache = cross_cache
    qpos = jnp.zeros((B, T), jnp.int32)
    kvpos = jnp.zeros((B, k.shape[1]), jnp.int32)
    out, _ = L.attention(q, k, v, qpos, kvpos, impl=cfg.attn_impl,
                         block_kv=cfg.attn_block_kv, causal=False)
    out = out.reshape(B, T, nh * hd) @ p["wo"]
    return out, new_cache


def _cross_layer(cfg, p, h, kv_src, cross_cache):
    a_in = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    a, ncc = _cross_attn(cfg, p["attn"], a_in, kv_src, cross_cache)
    h = h + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(h.dtype) * a
    f_in = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    f = L.mlp(p["mlp"], f_in)
    h = h + jnp.tanh(p["gate_ffn"].astype(jnp.float32)).astype(h.dtype) * f
    return h, (ncc if ncc is not None else {})


def _encdec_layer(cfg, p, h, pos, self_cache, kv_src, cross_cache, *,
                  window=0, ret_imp=False):
    a_in = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    a, nsc, imp = L.attn_block(p["self_attn"], a_in, pos, cfg, self_cache,
                               window=window, return_importance=ret_imp)
    h = h + a
    x_in = L.rms_norm(h, p["lnx"], cfg.norm_eps)
    xa, ncc = _cross_attn(cfg, p["cross_attn"], x_in, kv_src, cross_cache)
    h = h + xa
    f_in = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    return h + L.mlp(p["mlp"], f_in), nsc, ncc, imp


# ---------------------------------------------------------------------------
# Family backbones (scan over stacked layers)
# ---------------------------------------------------------------------------

def _maybe_ckpt(body, cfg, cache):
    return jax.checkpoint(body) if (cfg.remat and not cache) else body


def _uniform_stack(cfg, layers_p, h, pos, lcache, *, window, ret_imp, ffn):
    def body(carry, xs):
        hh, aux = carry
        lp, lc = xs
        hh, nc, imp, a = _layer(cfg, lp, hh, pos, _none(lc), window=window,
                                ret_imp=ret_imp, ffn=ffn)
        return (hh, aux + a), (nc if nc is not None else {},
                               imp if ret_imp else {})
    xs = (layers_p, lcache if lcache else {})
    (h, aux), (ncache, imps) = lax.scan(_maybe_ckpt(body, cfg, lcache),
                                        (h, jnp.zeros((), jnp.float32)), xs)
    imp = imps.mean(axis=0) if ret_imp else None
    return h, ncache, imp, aux


def _moe_interleaved(cfg, params, h, pos, cache, *, window, ret_imp):
    dcache = cache["dense"] if cache else {}
    mcache = cache["moe"] if cache else {}

    def body(carry, xs):
        hh, aux = carry
        dp, mp, dc, mc = xs
        hh, ndc, imp1, a1 = _layer(cfg, dp, hh, pos, _none(dc), window=window,
                                   ret_imp=ret_imp, ffn="mlp")
        hh, nmc, imp2, a2 = _layer(cfg, mp, hh, pos, _none(mc), window=window,
                                   ret_imp=ret_imp, ffn="moe")
        imp = (imp1 + imp2) / 2 if ret_imp else {}
        return (hh, aux + a1 + a2), (ndc if ndc is not None else {},
                                     nmc if nmc is not None else {}, imp)
    xs = (params["dense_layers"], params["moe_layers"], dcache, mcache)
    (h, aux), (ndc, nmc, imps) = lax.scan(
        _maybe_ckpt(body, cfg, cache), (h, jnp.zeros((), jnp.float32)), xs)
    ncache = {"dense": ndc, "moe": nmc} if cache else {}
    imp = imps.mean(axis=0) if ret_imp else None
    return h, ncache, imp, aux


def _vlm_backbone(cfg, params, h, pos, cache, img_embeds, *, window, ret_imp):
    kv_src = None
    if img_embeds is not None:
        kv_src = (img_embeds @ params["vision_proj"]).astype(h.dtype)
    scache = cache["self"] if cache else {}
    ccache = cache["cross"] if cache else {}

    def round_body(carry, xs):
        hh, aux = carry
        sp, cp, sc, cc = xs

        def inner(c2, xs2):
            h2, a2 = c2
            lp, lc = xs2
            h2, nc, imp, a = _layer(cfg, lp, h2, pos, _none(lc), window=window,
                                    ret_imp=ret_imp)
            return (h2, a2 + a), (nc if nc is not None else {},
                                  imp if ret_imp else {})
        (hh, aux), (nsc, imps) = lax.scan(inner, (hh, aux),
                                          (sp, sc if sc else {}))
        if img_embeds is not None:
            hh, ncc = _cross_layer(cfg, cp, hh, kv_src,
                                   _none(cc) if cache else None)
        else:
            hh, ncc = _cross_layer(cfg, cp, hh, None, _none(cc))
        return (hh, aux), (nsc, ncc, imps if ret_imp else {})

    xs = (params["self_layers"], params["cross_layers"], scache, ccache)
    (h, aux), (nsc, ncc, imps) = lax.scan(
        _maybe_ckpt(round_body, cfg, cache), (h, jnp.zeros((), jnp.float32)), xs)
    ncache = {"self": nsc, "cross": ncc} if cache else {}
    imp = imps.mean(axis=(0, 1)) if ret_imp else None
    return h, ncache, imp, aux


def _audio_encoder(cfg, params, frames):
    B, Ta, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Ta, dtype=jnp.int32)[None], (B, Ta))

    def body(hh, lp):
        a_in = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
        a, _, _ = L.attn_block(lp["attn"], a_in, pos, cfg, None, causal=False)
        hh = hh + a
        f_in = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
        return hh + L.mlp(lp["mlp"], f_in), None

    body = jax.checkpoint(body) if cfg.remat else body
    h, _ = lax.scan(body, frames, params["enc_layers"])
    return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _audio_backbone(cfg, params, h, pos, cache, frames, *, window, ret_imp):
    kv_src = _audio_encoder(cfg, params, frames) if frames is not None else None
    scache = cache["self"] if cache else {}
    ccache = cache["cross"] if cache else {}

    def body(carry, xs):
        hh = carry
        lp, sc, cc = xs
        hh, nsc, ncc, imp = _encdec_layer(
            cfg, lp, hh, pos, _none(sc), kv_src,
            _none(cc) if (cache or kv_src is None) else None,
            window=window, ret_imp=ret_imp)
        return hh, (nsc if nsc is not None else {},
                    ncc if ncc is not None else {},
                    imp if ret_imp else {})

    xs = (params["dec_layers"], scache, ccache)
    h, (nsc, ncc, imps) = lax.scan(_maybe_ckpt(body, cfg, cache), h, xs)
    ncache = {"self": nsc, "cross": ncc} if cache else {}
    imp = imps.mean(axis=0) if ret_imp else None
    return h, ncache, imp, jnp.zeros((), jnp.float32)


def _ssm_backbone(cfg, params, h, pos, cache, *, ret_imp):
    del pos

    def body(hh, xs):
        lp, lc = xs
        out, nc, imp = L.mamba_block(lp, cfg, hh, _none(lc),
                                     return_importance=ret_imp)
        return hh + out, (nc if nc is not None else {},
                          imp if ret_imp else {})
    xs = (params["layers"], cache["layers"] if cache else {})
    h, (nc, imps) = lax.scan(_maybe_ckpt(body, cfg, cache), h, xs)
    ncache = {"layers": nc} if cache else {}
    imp = imps.mean(axis=0) if ret_imp else None
    return h, ncache, imp, jnp.zeros((), jnp.float32)


def _hybrid_backbone(cfg, params, h, pos, cache, *, window, ret_imp):
    mcache = cache["mamba"] if cache else {}
    acache = cache["attn"] if cache else {}
    shared = params["shared_attn"]

    def round_body(carry, xs):
        hh = carry
        mp, mc, ac = xs

        def inner(h2, xs2):
            lp, lc = xs2
            out, nc, imp = L.mamba_block(lp, cfg, h2, _none(lc),
                                         return_importance=ret_imp)
            return h2 + out, (nc if nc is not None else {},
                              imp if ret_imp else {})
        hh, (nmc, imps_m) = lax.scan(inner, hh, (mp, mc if mc else {}))
        hh, nac, imp_a, _ = _layer(cfg, shared, hh, pos, _none(ac),
                                   window=window, ret_imp=ret_imp, mha=True)
        if ret_imp:
            imp = (imps_m.mean(axis=0) + imp_a) / 2
        else:
            imp = {}
        return hh, (nmc, nac if nac is not None else {}, imp)

    xs = (params["mamba_rounds"], mcache, acache)
    h, (nmc, nac, imps) = lax.scan(_maybe_ckpt(round_body, cfg, cache), h, xs)
    ncache = {"mamba": nmc, "attn": nac} if cache else {}
    imp = imps.mean(axis=0) if ret_imp else None
    return h, ncache, imp, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Top-level forward
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, positions, cache=None, aux_inputs=None, *,
            window: int = 0, return_importance: bool = False):
    """tokens: (B, T) int32; positions: (B, T) int32.

    Returns (logits (B, T, V), new_cache, importance, aux_loss).
    """
    aux_inputs = aux_inputs or {}
    h = jnp.take(params["embed"], tokens, axis=0)
    fam = cfg.family
    kw = dict(window=window, ret_imp=return_importance)

    if fam == "dense":
        h, nc, imp, aux = _uniform_stack(cfg, params["layers"], h, positions,
                                         cache["layers"] if cache else {},
                                         ffn="mlp", **kw)
        nc = {"layers": nc} if cache else {}
    elif fam == "moe":
        if cfg.moe_every == 1:
            h, nc, imp, aux = _uniform_stack(
                cfg, params["layers"], h, positions,
                cache["layers"] if cache else {}, ffn="moe", **kw)
            nc = {"layers": nc} if cache else {}
        else:
            h, nc, imp, aux = _moe_interleaved(cfg, params, h, positions,
                                               cache, **kw)
    elif fam == "vlm":
        h, nc, imp, aux = _vlm_backbone(cfg, params, h, positions, cache,
                                        aux_inputs.get("image_embeds"), **kw)
    elif fam == "audio":
        h, nc, imp, aux = _audio_backbone(cfg, params, h, positions, cache,
                                          aux_inputs.get("audio_frames"), **kw)
    elif fam == "ssm":
        h, nc, imp, aux = _ssm_backbone(cfg, params, h, positions, cache,
                                        ret_imp=return_importance)
    elif fam == "hybrid":
        h, nc, imp, aux = _hybrid_backbone(cfg, params, h, positions, cache,
                                           **kw)
    else:
        raise ValueError(fam)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["unembed"]
    logits = shardctx.constrain(logits, "logits")
    return logits, (nc if cache else None), imp, aux


def default_positions(batch: int, seq: int):
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------

def lm_loss(cfg, params, batch, *, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux loss)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    pos = default_positions(B, T)
    aux_inputs = {k: batch[k] for k in ("image_embeds", "audio_frames")
                  if k in batch}
    logits, _, _, aux = forward(cfg, params, tokens, pos,
                                aux_inputs=aux_inputs)
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    # cross entropy via logsumexp without materializing a f32 copy of the
    # full (B, T, V) logits (that copy dominated train-step HBM: 537 GB
    # global for a 128k vocab at 1M tokens)
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt.astype(jnp.float32)
    loss = nll.mean()
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}
