"""Neural building blocks shared by every architecture family.

Everything is a pure function over explicit parameter pytrees (nested
dicts of jnp arrays).  Layer stacks are consumed via ``lax.scan`` over
stacked parameters (see model.py), so each function here must be
shape-polymorphic in the batch/sequence dims but static in config.

Attention exists in three implementations (a hillclimb lever, see
EXPERIMENTS.md §Perf):
  * ``naive``   -- materializes softmax(QK^T); required when the caller
                   wants the paper's *importance score* (column sums of the
                   attention matrix, §3.2 of Synera), which the flash
                   pattern never materializes.  Used on the device SLM
                   (short contexts) and as the paper-faithful baseline.
  * ``blocked`` -- online-softmax scan over KV blocks (flash pattern at
                   the HLO level): O(block) memory, the optimized cloud
                   path on any backend.
  * ``pallas``  -- the hand-written TPU kernels in repro/kernels:
                   ``decode_gqa`` for T==1 cached decode,
                   ``partial_prefill`` for chunked verification, and
                   ``attn_importance`` for the device draft path
                   (interpret-mode fallback off-TPU; non-causal shapes
                   fall back to ``blocked``).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import shardctx

NEG_INF = -1e30


def _f32(x):
    return x.astype(jnp.float32)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    xf = _f32(x)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * _f32(w)).astype(x.dtype)


def gated_rms_norm(y, z, w, eps: float = 1e-5):
    """Mamba2 gated RMSNorm: norm(y * silu(z)) * w."""
    return rms_norm(y * silu(z), w, eps)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: (B, T, n_heads, head_dim); positions: (B, T) int32."""
    hd = x.shape[-1]
    half = hd // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(_f32(x), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, window: int, causal: bool):
    """(B, Tq, S) additive bias; kv_pos < 0 marks invalid slots."""
    valid = kv_pos[:, None, :] >= 0
    if causal:
        valid &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        valid &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    return jnp.where(valid, 0.0, NEG_INF)


def _expand_kv(k, g: int):
    """(B, S, nkv, hd) -> (B, S, nh, hd).  GQA K/V repeated to full heads.

    NOTE (§Perf iteration 1): the grouped form — q reshaped to
    (B, T, nkv, g, hd) and einsum'd against un-repeated K/V — misaligns
    with tensor-parallel sharding: nh*hd sharded 16-way cuts inside a
    (g, hd) group when nkv < mesh "model" size, and XLA falls back to
    full replication of attention on every device (measured 256x
    per-device FLOP inflation at 4k train).  Ungrouped heads with an
    explicit repeat shard cleanly (nh divisible by the axis); the repeat
    is a broadcast XLA optimizes away on the memory side.
    """
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def naive_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    causal: bool = True, return_importance: bool = False):
    """Materialized attention (GROUPED GQA einsum — K/V never expanded).

    q: (B, Tq, nh, hd); k, v: (B, S, nkv, hd).
    Returns (out (B, Tq, nh, hd), importance (B, S) or None).
    Importance = column-wise sum of the softmax matrix, averaged over
    heads and summed over query rows (Synera §3.2 / Fig 2).

    §Perf note (decode hillclimb): this path serves decode (Tq = 1),
    where the whole computation should stay batch-sharded — expanding
    K/V to nh heads (as the blocked path does for tensor-parallel
    training) made XLA reshard the f32-expanded cache across the model
    axis, an all-gather of the entire KV cache (17 GB for a 1B model)
    EVERY decode step.  The grouped einsum keeps K/V in its cache layout.
    """
    B, Tq, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    qg = _f32(q).reshape(B, Tq, nkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, _f32(k)) * scale
    bias = _mask_bias(q_pos, kv_pos, window, causal)  # (B, Tq, S)
    s = s + bias[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)  # (B, nkv, g, Tq, S)
    out = jnp.einsum("bkgts,bskd->btkgd", p, _f32(v)).reshape(B, Tq, nh, hd)
    imp = None
    if return_importance:
        # mean over heads, sum over query rows -> per-key importance
        imp = p.mean(axis=(1, 2)).sum(axis=1)  # (B, S)
    return out.astype(q.dtype), imp


def blocked_attention(q, k, v, q_pos, kv_pos, *, block_kv: int = 1024,
                      window: int = 0, causal: bool = True):
    """Online-softmax attention, scanning KV blocks (flash pattern)."""
    B, Tq, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    bk = min(block_kv, S)
    nb = -(-S // bk)
    pad = nb * bk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)

    qf = _f32(q) * scale                      # (B, Tq, nh, hd)
    kb = k.reshape(B, nb, bk, nkv, hd)
    vb = v.reshape(B, nb, bk, nkv, hd)
    pb = kv_pos.reshape(B, nb, bk)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, pblk = xs  # (B, bk, nkv, hd), (B, bk)
        kf = _expand_kv(_f32(kblk), g)        # (B, bk, nh, hd)
        vf = _expand_kv(_f32(vblk), g)
        s = jnp.einsum("bthd,bshd->bhts", qf, kf)
        bias = _mask_bias(q_pos, pblk, window, causal)  # (B, Tq, bk)
        s = s + bias[:, None, :, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", p, vf)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nh, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nh, Tq), jnp.float32)
    a0 = jnp.zeros((B, nh, Tq, hd), jnp.float32)
    # scan over the block axis (moved to front); pin batch sharding to
    # axis 1 so SPMD never shards the scanned block axis (see shardctx)
    xs = (shardctx.constrain_batch_dim(jnp.moveaxis(kb, 1, 0), 1),
          shardctx.constrain_batch_dim(jnp.moveaxis(vb, 1, 0), 1),
          shardctx.constrain_batch_dim(jnp.moveaxis(pb, 1, 0), 1))
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), xs)
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 2, 1, 3).reshape(B, Tq, nh, hd)
    return out.astype(q.dtype)


def pallas_attention(q, k, v, q_pos, kv_pos, *, block_kv: int = 1024,
                     window: int = 0, causal: bool = True,
                     return_importance: bool = False):
    """Dispatch to the repro/kernels Pallas kernels (cfg.attn_impl ==
    "pallas"):

    * ``attn_importance``  -- importance extraction fused into attention
      (device SLM draft path; whole KV VMEM-resident, window unsupported)
    * ``decode_gqa``       -- T == 1 cached decode (KV streamed per group)
    * ``partial_prefill``  -- chunked verification over a cached prefix

    Falls back to the XLA paths for shapes the kernels don't cover
    (non-causal cross attention; windowed importance).
    """
    # deferred imports: kernels are an optional acceleration layer and
    # must not be imported for the default XLA-only configs
    from repro.kernels.attn_importance.attn_importance import (
        attn_with_importance)
    from repro.kernels.decode_gqa.decode_gqa import decode_attention
    from repro.kernels.partial_prefill.partial_prefill import (
        partial_prefill_attention)

    q_pos = q_pos.astype(jnp.int32)
    kv_pos = kv_pos.astype(jnp.int32)
    if return_importance:
        if window:
            return naive_attention(q, k, v, q_pos, kv_pos, window=window,
                                   causal=causal, return_importance=True)
        out, imp = attn_with_importance(q, k, v, q_pos, kv_pos,
                                        causal=causal)
        # paper importance (§3.2): head mean of per-head column sums
        return out, imp.mean(axis=1)
    if q.shape[1] == 1:
        out = decode_attention(q[:, 0], k, v, q_pos[:, 0], kv_pos,
                               window=window, block_kv=block_kv)
        return out[:, None], None
    out = partial_prefill_attention(q, k, v, q_pos, kv_pos, window=window,
                                    block_kv=block_kv)
    return out, None


def attention(q, k, v, q_pos, kv_pos, *, impl: str = "blocked",
              block_kv: int = 1024, window: int = 0, causal: bool = True,
              return_importance: bool = False):
    if impl == "pallas" and causal:
        return pallas_attention(q, k, v, q_pos, kv_pos, block_kv=block_kv,
                                window=window, causal=causal,
                                return_importance=return_importance)
    if return_importance or impl == "naive":
        return naive_attention(q, k, v, q_pos, kv_pos, window=window,
                               causal=causal,
                               return_importance=return_importance)
    out = blocked_attention(q, k, v, q_pos, kv_pos, block_kv=block_kv,
                            window=window, causal=causal)
    return out, None


# ---------------------------------------------------------------------------
# KV cache helpers
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, s_max: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, s_max), -1, jnp.int32),
    }


def cache_write(cache, k_new, v_new, positions):
    """Write new K/V at slots positions % S_max (circular when windowed).

    Negative positions mark padding (the engine pads ragged verification
    chunks to the Sarathi chunk size); they map to an out-of-bounds slot,
    which XLA scatter drops — padded tokens never pollute the cache.
    """
    s_max = cache["k"].shape[1]
    B = k_new.shape[0]
    slot = jnp.where(positions >= 0, positions % s_max, s_max + 7)  # (B, T)
    b_idx = jnp.arange(B)[:, None]
    return {
        "k": cache["k"].at[b_idx, slot].set(k_new.astype(cache["k"].dtype)),
        "v": cache["v"].at[b_idx, slot].set(v_new.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[b_idx, slot].set(positions),
    }


# -- paged (block-pool) cache: vLLM/PagedAttention layout -------------------

def is_paged(cache) -> bool:
    """A paged per-layer cache carries a ``block_tables`` leaf."""
    return cache is not None and "block_tables" in cache


def cache_write_paged(cache, k_new, v_new, positions):
    """Scatter new K/V into the shared block pool through each slot's
    block table.

    cache: {"k"/"v": (nb, bs, nkv, hd), "pos": (nb, bs),
            "block_tables": (B, max_bps)}.  Token at absolute position p
    lives at virtual slot ``v = p % s_max`` (circular when windowed),
    i.e. pool block ``block_tables[b, v // bs]``, row ``v % bs``.
    Padding (position -1) and unmapped table entries (-1) route to an
    out-of-bounds pool index, which XLA scatter drops — exactly the
    dense ``cache_write`` contract.
    """
    nb, bs = cache["k"].shape[0], cache["k"].shape[1]
    bt = cache["block_tables"]                   # (B, max_bps)
    s_max = bt.shape[1] * bs
    B = k_new.shape[0]
    vslot = jnp.where(positions >= 0, positions % s_max, 0)   # (B, T)
    b_idx = jnp.arange(B)[:, None]
    entry = bt[b_idx, vslot // bs]               # (B, T) pool block ids
    blk = jnp.where((positions >= 0) & (entry >= 0), entry, nb)  # OOB drop
    local = vslot % bs
    return {
        "k": cache["k"].at[blk, local].set(k_new.astype(cache["k"].dtype)),
        "v": cache["v"].at[blk, local].set(v_new.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[blk, local].set(positions),
        "block_tables": bt,
    }


def cache_copy_blocks(stack, src, dst):
    """Copy pool blocks ``src[i] -> dst[i]`` within one paged kv stack —
    the copy-on-write fork primitive: before a slot writes into a block
    with refcount > 1, the allocator points it at a fresh block and the
    engine clones the shared content with this (jitted, donated) copy.

    ``src``/``dst``: (m,) int32, -1-padded.  A padded pair routes the
    destination out of bounds, which XLA scatter drops (the clamped
    source row is gathered but never lands anywhere).
    """
    nb = stack["k"].shape[1]
    s = jnp.clip(src, 0, nb - 1)
    d = jnp.where(dst >= 0, dst, nb)
    out = dict(stack)
    for key in ("k", "v", "pos"):
        out[key] = stack[key].at[:, d].set(stack[key][:, s])
    return out


def cache_copy_block_rows(stack, src, dst, rows):
    """Partial-block tail copy within one paged kv stack: for each pair,
    clone the first ``rows[i]`` token rows of pool block ``src[i]`` into
    block ``dst[i]`` (k/v/pos together), leaving dst's remaining rows
    untouched.  This is the sub-block sharing primitive: a new stream
    whose prompt diverges mid-block adopts the matched leading rows of a
    registered block *by value* instead of re-computing them (positions
    copy verbatim — chained prefix blocks share absolute positions).

    ``src``/``dst``: (m,) int32, -1-padded; ``rows``: (m,) int32 (0 for
    padding).  Padded or empty pairs route the destination out of
    bounds, which XLA scatter drops.
    """
    nb, bs = stack["k"].shape[1], stack["k"].shape[2]
    s = jnp.clip(src, 0, nb - 1)
    d_read = jnp.clip(dst, 0, nb - 1)
    d = jnp.where((dst >= 0) & (rows > 0), dst, nb)
    mask = jnp.arange(bs)[None, :] < rows[:, None]          # (m, bs)
    out = dict(stack)
    for key in ("k", "v", "pos"):
        src_c = stack[key][:, s]                  # (layers, m, bs, ...)
        dst_c = stack[key][:, d_read]
        m = mask.reshape((1,) + mask.shape + (1,) * (src_c.ndim - 3))
        out[key] = stack[key].at[:, d].set(jnp.where(m, src_c, dst_c))
    return out


def cache_peek_blocks(stack, blocks):
    """Read-only gather of pool blocks ``blocks[i]`` (k/v/pos) from one
    paged kv stack.  Unlike :func:`cache_gather_blocks` the pool is NOT
    invalidated — the content-addressed host tier uses this to demote a
    block's bytes to host memory while the device copy stays live (a
    cached-free block keeps serving device-tier hits until reclaimed).
    ``blocks``: (m,) int32, -1-padded (padded rows gather clamped junk
    the caller ignores)."""
    nb = stack["k"].shape[1]
    s = jnp.clip(blocks, 0, nb - 1)
    return {key: stack[key][:, s] for key in ("k", "v", "pos")}


def cache_gather_blocks(stack, blocks):
    """Gather pool blocks ``blocks[i]`` out of one paged kv stack (the
    swap-out primitive: the host swap tier keeps the gathered k/v/pos
    while the pool blocks go back to the free list).  The gathered
    blocks' pool positions are invalidated in the same dispatch — a
    swapped-out block must never read as valid through a future owner's
    table.

    ``blocks``: (m,) int32, -1-padded.  Padded entries gather a clamped
    row (the caller ignores it) and invalidate nothing (the pad routes
    the scatter out of bounds).  Returns ``(payload, new_stack)`` with
    ``payload = {k/v/pos: (layers, m, bs, ...)}``.
    """
    nb = stack["k"].shape[1]
    s = jnp.clip(blocks, 0, nb - 1)
    payload = {key: stack[key][:, s] for key in ("k", "v", "pos")}
    inv = jnp.where(blocks >= 0, blocks, nb)       # OOB pad: scatter drops
    new = dict(stack)
    new["pos"] = stack["pos"].at[:, inv].set(-1)
    return payload, new


def cache_scatter_blocks(stack, blocks, payload):
    """Scatter a swapped-out payload back into pool blocks ``blocks[i]``
    of one paged kv stack (the swap-in primitive; k/v/pos land together,
    so the restored blocks are bit-identical to what was gathered).
    ``blocks``: (m,) int32, -1-padded; padded pairs route out of bounds
    and are dropped, exactly like :func:`cache_copy_blocks`."""
    nb = stack["k"].shape[1]
    d = jnp.where(blocks >= 0, blocks, nb)
    new = dict(stack)
    for key in ("k", "v", "pos"):
        new[key] = stack[key].at[:, d].set(payload[key].astype(
            stack[key].dtype))
    return new


def paged_kv_view(cache):
    """Gather a slot-major (B, s_max, ...) view of the paged pool — the
    XLA read path.  Unmapped table entries (-1) are forced out of bounds
    (negative indices would wrap under jnp.take's fill mode) and read as
    K/V = 0, pos = -1, i.e. masked — the gathered view is element-wise
    identical to the dense cache after the same writes.
    """
    bt = cache["block_tables"]                   # (B, max_bps)
    nb = cache["k"].shape[0]
    btc = jnp.where(bt < 0, nb, bt)
    k = jnp.take(cache["k"], btc, axis=0, mode="fill", fill_value=0)
    v = jnp.take(cache["v"], btc, axis=0, mode="fill", fill_value=0)
    pos = jnp.take(cache["pos"], btc, axis=0, mode="fill", fill_value=-1)
    B, mb, bs = pos.shape
    return (k.reshape(B, mb * bs, *k.shape[3:]),
            v.reshape(B, mb * bs, *v.shape[3:]),
            pos.reshape(B, mb * bs))


def paged_pallas_attention(q, cache, q_pos, *, window: int = 0,
                           block_kv: int | None = None, kv_splits: int = 1):
    """Dispatch the block-table-aware Pallas kernels over the pool
    directly (no gathered copy is materialized): ``decode_gqa`` for
    T == 1, ``partial_prefill`` for verification chunks.  ``block_kv``
    sets the fused-DMA width (table entries per grid step =
    ``block_kv // kv_block_size``); ``kv_splits`` the flash-decode
    split-KV parallelism.  Interpret-mode fallback off-TPU, same as the
    dense kernels."""
    from repro.kernels.decode_gqa.decode_gqa import decode_attention_paged
    from repro.kernels.partial_prefill.partial_prefill import (
        partial_prefill_attention_paged)

    q_pos = q_pos.astype(jnp.int32)
    k, v = cache["k"], cache["v"]
    pos, bt = cache["pos"], cache["block_tables"]
    if q.shape[1] == 1:
        out = decode_attention_paged(q[:, 0], k, v, q_pos[:, 0], pos, bt,
                                     window=window, block_kv=block_kv,
                                     kv_splits=kv_splits)
        return out[:, None]
    return partial_prefill_attention_paged(q, k, v, q_pos, pos, bt,
                                           window=window, block_kv=block_kv,
                                           kv_splits=kv_splits)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache + core)
# ---------------------------------------------------------------------------

def init_attn(key, d_model, n_heads, n_kv, head_dim, *, bias=False, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * head_dim)) * sd).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv * head_dim)) * sd).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv * head_dim)) * sd).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * head_dim, d_model)) * sd
               / math.sqrt(2.0)).astype(dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attn_block(p, x, positions, cfg, cache=None, *, kv_x=None, kv_pos=None,
               causal=True, rope=True, window=0, return_importance=False,
               n_heads=None, n_kv=None):
    """Self- or cross-attention with optional cache.

    x: (B, T, d).  If ``kv_x`` is given, keys/values come from it
    (cross-attention).  If ``cache`` is given, new K/V are written into it
    and attention runs over the whole buffer.
    Returns (out, new_cache, importance).
    """
    nh = n_heads if n_heads is not None else cfg.n_heads
    nkv = n_kv if n_kv is not None else cfg.n_kv_heads
    hd = cfg.head_dim
    B, T, _ = x.shape

    q = x @ p["wq"]
    src = x if kv_x is None else kv_x
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, T, nh, hd)
    k = k.reshape(B, src.shape[1], nkv, hd)
    v = v.reshape(B, src.shape[1], nkv, hd)

    if kv_x is None:
        src_pos = positions if kv_pos is None else kv_pos
    else:
        src_pos = (jnp.zeros((B, src.shape[1]), jnp.int32)
                   if kv_pos is None else kv_pos)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = apply_rope(k, src_pos, cfg.rope_theta)

    new_cache = None
    if cache is not None and is_paged(cache):
        new_cache = cache_write_paged(cache, k, v, positions)
        if (cfg.attn_impl == "pallas" and causal and not return_importance):
            # block-table-aware kernels read the pool in place — the
            # (B, s_max) gathered copy is never materialized
            out = paged_pallas_attention(
                q, new_cache, positions, window=window,
                block_kv=getattr(cfg, "paged_block_kv", None),
                kv_splits=getattr(cfg, "paged_kv_splits", 1))
            out = out.reshape(B, T, nh * hd) @ p["wo"]
            return out, new_cache, None
        k_all, v_all, kv_positions = paged_kv_view(new_cache)
    elif cache is not None:
        new_cache = cache_write(cache, k, v, positions)
        k_all, v_all, kv_positions = new_cache["k"], new_cache["v"], new_cache["pos"]
    else:
        k_all, v_all, kv_positions = k, v, src_pos

    out, imp = attention(
        q, k_all, v_all, positions, kv_positions,
        impl=cfg.attn_impl, block_kv=cfg.attn_block_kv, window=window,
        causal=causal, return_importance=return_importance)
    out = out.reshape(B, T, nh * hd) @ p["wo"]
    return out, new_cache, imp


# ---------------------------------------------------------------------------
# FFN: dense SwiGLU and MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = 1.0 / math.sqrt(d_model)
    s2 = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s1).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s1).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s2).astype(dtype),
    }


def mlp(p, x):
    return (silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_moe(key, d_model, d_ff, n_experts, *, n_shared=0, dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s1 = 1.0 / math.sqrt(d_model)
    s2 = 1.0 / math.sqrt(d_ff)
    p = {
        "router": (jax.random.normal(k1, (d_model, n_experts)) * s1).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (n_experts, d_model, d_ff)) * s1).astype(dtype),
        "w_up": (jax.random.normal(k3, (n_experts, d_model, d_ff)) * s1).astype(dtype),
        "w_down": (jax.random.normal(k4, (n_experts, d_ff, d_model)) * s2).astype(dtype),
    }
    if n_shared:
        p["shared"] = init_mlp(k5, d_model, n_shared * d_ff, dtype=dtype)
    return p


def moe_ffn(p, x, *, top_k: int):
    """Token-choice top-k MoE with sort + ragged_dot grouped matmul.

    x: (B, T, d).  Returns (out, aux_loss).  FLOPs proportional to
    *active* experts (no capacity drop), which keeps the roofline honest.

    §Perf iteration (qwen3-moe hillclimb): every dispatch intermediate is
    pinned to token-dim sharding over the data axes — without the
    constraints XLA replicates the whole sort/gather/grouped-matmul
    pipeline on all devices (measured 111x per-device FLOP inflation and
    144 TB/device of all-reduce at 4k train).
    """
    B, T, d = x.shape
    E = p["router"].shape[1]
    xf = shardctx.constrain_batch_dim(x.reshape(-1, d), 0)
    N = xf.shape[0]

    logits = _f32(xf) @ p["router"]  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, top_k)  # (N, k)
    top_p = top_p / top_p.sum(axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1).astype(jnp.int32)           # (N*k,)
    order = shardctx.constrain_batch_dim(jnp.argsort(flat_e), 0)
    tok = order // top_k                                   # source token
    xs = shardctx.constrain_batch_dim(jnp.take(xf, tok, axis=0), 0)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    g = lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = lax.ragged_dot(xs, p["w_up"], group_sizes)
    h = shardctx.constrain_batch_dim(silu(g) * u, 0)
    ys = shardctx.constrain_batch_dim(
        lax.ragged_dot(h, p["w_down"], group_sizes), 0)    # (N*k, d)

    w = jnp.take(top_p.reshape(-1), order).astype(ys.dtype)
    out = jnp.zeros_like(xf).at[tok].add(ys * w[:, None])
    out = shardctx.constrain_batch_dim(out, 0)

    if "shared" in p:
        out = out + mlp(p["shared"], xf)

    # Switch-style load-balance auxiliary loss
    frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    return out.reshape(B, T, d), aux


def moe_ffn_ep(p, x, *, top_k: int, capacity_factor: float = 2.0):
    """Expert-parallel MoE via an explicit shard_map region (§Perf
    iteration 3, the winning MoE formulation).

    Layout: experts E over "model" (each model rank owns E/msz experts),
    expert d over "data" (FSDP: gathered per layer inside the region),
    tokens over the data axes.  Each device computes, for its LOCAL
    tokens, the contributions of its OWN experts only (masked local
    assignments, fixed capacity C = N_loc*k/msz*cf, sorted ragged_dot),
    then one psum over "model" combines expert contributions.  No token
    all-to-all, no global sort — the two things XLA's auto-partitioner
    could not handle (measured 111x FLOP replication with ragged_dot
    under auto SPMD).

    Requires the "moe_mesh" shardctx hint; falls back to the single-host
    path otherwise.  Capacity overflow tokens are dropped per local
    expert (GShard semantics, cf=2 default) — acceptable for training,
    disabled criticality for the smoke tests which use the auto path.
    """
    hint = shardctx.get("moe_mesh")
    if hint is None:
        return moe_ffn(p, x, top_k=top_k)
    mesh, data_axes = hint
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msz = axes.get("model", 1)
    B, T, d = x.shape
    E = p["router"].shape[1]
    E_loc = E // msz
    dsz = 1
    for a in data_axes:
        dsz *= axes.get(a, 1)
    dff = p["w_gate"].shape[-1]
    if (E_loc * msz != E or B % dsz != 0 or d % dsz != 0
            or p["w_down"].shape[-1] % dsz != 0):
        return moe_ffn(p, x, top_k=top_k)
    B_loc = B // dsz
    N_loc = B_loc * T
    C = max(int(N_loc * top_k / msz * capacity_factor), 8)
    C = -(-C // 8) * 8
    C = min(C, N_loc * top_k)   # cannot keep more assignments than exist

    def region(xl, router, wg, wu, wd):
        # xl: (B_loc, T, d); router: (d, E);
        # wg/wu: (E_loc, d_loc, dff); wd: (E_loc, dff, d_loc)
        xf = xl.reshape(N_loc, d)
        logits = _f32(xf) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = lax.top_k(probs, top_k)
        top_p = top_p / top_p.sum(axis=-1, keepdims=True)

        r = lax.axis_index("model")
        e_flat = top_e.reshape(-1).astype(jnp.int32)       # (N_loc*k,)
        w_flat = top_p.reshape(-1)
        local = (e_flat // E_loc) == r
        e_loc = jnp.where(local, e_flat - r * E_loc, E_loc)  # E_loc = inval
        order = jnp.argsort(e_loc)                         # invalid last
        keep = order[:C]
        e_keep = e_loc[keep]                               # sorted, (C,)
        valid = e_keep < E_loc
        tok = keep // top_k
        xs = jnp.take(xf, tok, axis=0)                     # (C, d)
        gs = jnp.bincount(jnp.where(valid, e_keep, E_loc),
                          length=E_loc + 1)[:E_loc].astype(jnp.int32)

        # expert d is sharded over "data" only (pod-replicated): gather
        # exactly that axis (multi-pod data_axes include "pod")
        wg_f = lax.all_gather(wg, "data", axis=1, tiled=True)
        wu_f = lax.all_gather(wu, "data", axis=1, tiled=True)
        wd_f = lax.all_gather(wd, "data", axis=2, tiled=True)

        # §Perf iteration 4: capacity-bucketed grouped matmul.
        # lax.ragged_dot lowers densely on this backend (every row times
        # ALL local experts: measured 8x FLOP waste); scattering the
        # sorted rows into fixed (E_loc, Ce, d) buckets and einsum'ing
        # gives exact grouped-matmul FLOPs on any backend.  Per-expert
        # capacity Ce = C/E_loc (drop-on-overflow, GShard semantics; the
        # aux loss drives balance).
        # tiny chunks (decode): let any expert take every row; large
        # batches: balanced per-expert capacity
        Ce = C if C <= 256 else max(C // E_loc, 8)
        e_clamped = jnp.where(valid, e_keep, 0)
        offs = jnp.concatenate([jnp.zeros((1,), gs.dtype),
                                jnp.cumsum(gs)[:-1]])
        slot = jnp.arange(C, dtype=jnp.int32) - offs[e_clamped]
        in_cap = valid & (slot < Ce) & (slot >= 0)
        slot_w = jnp.where(in_cap, slot, Ce)     # Ce = OOB -> scatter-drop
        buf = jnp.zeros((E_loc, Ce, d), xs.dtype).at[e_clamped, slot_w].set(xs)
        g = jnp.einsum("ecd,edf->ecf", buf, wg_f)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_f)
        h = silu(g) * u
        ys_buf = jnp.einsum("ecf,efd->ecd", h, wd_f)       # (E_loc, Ce, d)
        ys = ys_buf[e_clamped, jnp.minimum(slot_w, Ce - 1)]  # (C, d)

        wk = jnp.where(in_cap, jnp.take(w_flat, keep), 0.0).astype(ys.dtype)
        out = jnp.zeros((N_loc, d), ys.dtype).at[tok].add(ys * wk[:, None])
        out = lax.psum(out, "model")

        frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                        axis=(0, 1))
        mean_prob = probs.mean(axis=0)
        frac = lax.pmean(frac, data_axes)
        mean_prob = lax.pmean(mean_prob, data_axes)
        aux = E * jnp.sum(frac * mean_prob)
        return out.reshape(B_loc, T, d).astype(xl.dtype), aux

    from jax.sharding import PartitionSpec as P
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]
    out, aux = jax.shard_map(
        region, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        out = out + mlp(p["shared"], x.reshape(-1, d)).reshape(B, T, d)
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype=jnp.float32):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    convC = di + 2 * N
    keys = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": (jax.random.normal(keys[0], (d, 2 * di + 2 * N + H)) * s).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.ssm_conv_width, convC)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((convC,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(keys[2], (di, d)) / math.sqrt(di)).astype(dtype),
    }


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B, L, C), w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return y + b


def conv_step(conv_state, x_t, w, b):
    """conv_state: (B, W-1, C), x_t: (B, T, C) with T small (decode chunk)."""
    full = jnp.concatenate([conv_state, x_t], axis=1)
    y = causal_conv1d(full, w, b)[:, conv_state.shape[1]:, :]
    W1 = conv_state.shape[1]
    new_state = full[:, -W1:, :] if W1 else conv_state
    return y, new_state


def _segsum(dA):
    """dA: (..., q, h) -> L (..., h, q, q) with L[i,j]=exp(sum_{j<k<=i} dA).

    The masked (j > i) entries have POSITIVE diff (dA is negative), so
    exp overflows there; masking must happen BEFORE the exp or its
    gradient is NaN (the where-grad trap)."""
    q = dA.shape[-2]
    dAc = jnp.cumsum(dA, axis=-2)  # (..., q, h)
    dAc = jnp.moveaxis(dAc, -1, -2)  # (..., h, q)
    diff = dAc[..., :, None] - dAc[..., None, :]  # (..., h, q, q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.exp(jnp.where(mask, diff, NEG_INF))


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, h0=None):
    """Chunked SSD scan ("Transformers are SSMs", Alg. 1 / minimal impl).

    x: (B, L, H, P); dt: (B, L, H) (already softplus'd);
    A: (H,) negative; Bm, Cm: (B, L, N) (single group).
    Returns (y (B, L, H, P), h_final (B, H, P, N)).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    C_ = L // Q

    xf, dtf = _f32(x), _f32(dt)
    Bf, Cf = _f32(Bm), _f32(Cm)
    dA = dtf * A  # (B, L, H)

    xc = xf.reshape(Bsz, C_, Q, H, P)
    dtc = dtf.reshape(Bsz, C_, Q, H)
    dAc = dA.reshape(Bsz, C_, Q, H)
    Bc = Bf.reshape(Bsz, C_, Q, N)
    Cc = Cf.reshape(Bsz, C_, Q, N)

    # Intra-chunk (dual quadratic form)
    Lmat = _segsum(dAc)  # (B, C, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # (B, C, Q, Q)
    Yd = jnp.einsum("bcqk,bchqk,bckh,bckhp->bcqhp",
                    scores, Lmat, dtc, xc)

    # Chunk states
    dA_cum = jnp.cumsum(dAc, axis=2)  # (B, C, Q, H)
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B, C, Q, H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, decay_states * dtc, xc)

    # Inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # (B, C, H)

    def body(h, xs):
        st, dec = xs  # (B, H, P, N), (B, H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    hinit = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else _f32(h0))
    h_fin, h_prev = lax.scan(
        body, hinit,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B, C, H, P, N)

    # Off-diagonal (inter-chunk) contribution
    Yo = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, h_prev, jnp.exp(dA_cum))
    y = (Yd + Yo).reshape(Bsz, L, H, P)
    return y.astype(x.dtype), h_fin


def ssd_decode(x, dt, A, Bm, Cm, h):
    """Single-token recurrent update.

    x: (B, 1, H, P), dt: (B, 1, H), Bm/Cm: (B, 1, N), h: (B, H, P, N).
    """
    xf, dtf = _f32(x[:, 0]), _f32(dt[:, 0])  # (B,H,P), (B,H)
    Bf, Cf = _f32(Bm[:, 0]), _f32(Cm[:, 0])  # (B,N)
    dA = jnp.exp(dtf * A)  # (B, H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, Bf)
    h_new = h * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cf, h_new)
    return y[:, None].astype(x.dtype), h_new


def mamba_block(p, cfg, x, cache=None, *, return_importance=False):
    """Full Mamba2 block. x: (B, T, d).

    cache: {"conv": (B, W-1, C), "state": (B, H, P, N)} or None.
    Importance analogue for SSMs (see DESIGN.md §Arch-applicability):
    per-token |dt * x| contribution magnitude.
    """
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    B, T, _ = x.shape

    proj = x @ p["in_proj"]
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [di + 2 * N], axis=-1)

    new_conv = None
    if cache is not None:
        xbc, new_conv = conv_step(cache["conv"], xbc, p["conv_w"], p["conv_b"])
    else:
        xbc = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xbc = silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)

    dt = jax.nn.softplus(_f32(dt_raw) + p["dt_bias"])  # (B, T, H)
    A = -jnp.exp(p["A_log"])  # (H,)
    xh = xs.reshape(B, T, H, P)

    new_state = None
    if cache is not None and T == 1:
        y, new_state = ssd_decode(xh, dt, A, Bm, Cm, _f32(cache["state"]))
    else:
        h0 = _f32(cache["state"]) if cache is not None else None
        Q = min(cfg.ssm_chunk, T)
        pad = (-T) % Q
        if pad:
            # dt=0 on padded steps => decay exp(0)=1, update dt*B*x = 0:
            # padded tail is a no-op on the state.
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            y, new_state = ssd_chunked(xh_p, dt_p, A, Bm_p, Cm_p, chunk=Q, h0=h0)
            y = y[:, :T]
        else:
            y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, chunk=Q, h0=h0)
    y = y + (p["D"][:, None] * _f32(xh)).astype(y.dtype)
    y = y.reshape(B, T, di)
    y = gated_rms_norm(y, z, p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]

    imp = None
    if return_importance:
        imp = jnp.mean(jnp.abs(dt[..., None] * _f32(xh)), axis=(-1, -2))  # (B, T)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": new_state.astype(cache["state"].dtype)}
    return out, new_cache, imp


def init_mamba_cache(cfg, batch, dtype):
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    convC = di + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, convC), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }
