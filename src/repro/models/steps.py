"""Step factories: the jit-able entry points used by the launcher, the
serving engine, and the multi-pod dry-run.

Every factory closes over the static config and returns a pure function
of (params, state/batch) suitable for ``jax.jit(..., in_shardings=...)``.

Cache substrate: the serving steps are layout-agnostic — the cache
pytree they thread through ``model.forward`` is either the dense
``(slots, s_max)`` buffer or the paged block pool + per-slot block
tables (``cfg.cache_impl="paged"``), and attention reads/writes route
through the tables structurally (layers.cache_write_paged /
paged_kv_view / the block-table Pallas kernels).  The engine mutates
only the ``block_tables`` leaves between calls, so the jitted steps
never re-specialize on allocation changes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M


def make_train_step(cfg, optimizer, *, micro_batches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    micro_batches > 1 enables in-step gradient accumulation (§Perf
    iteration 7): the global batch is scanned in micro-batches so live
    activations shrink by the accumulation factor (94-layer 235B MoE at
    1M tokens needs ~147 GiB/device of activations without it; v5e HBM
    is 16 GiB).  Semantics are identical up to f32 grad-mean order.
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if micro_batches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbatch = jax.tree.map(
                lambda x: x.reshape((micro_batches,
                                     x.shape[0] // micro_batches)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (loss_i, metrics_i), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, (loss_i, metrics_i)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(body, acc0, mbatch)
            grads = jax.tree.map(lambda g: g / micro_batches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricses)
        params, opt_state, opt_m = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_m)
        return params, opt_state, metrics

    return train_step


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        loss, metrics = M.lm_loss(cfg, params, batch)
        return loss, metrics
    return loss_fn


def make_prefill_step(cfg):
    """(params, cache, tokens, aux) -> (logits_last, cache).

    tokens: (B, T).  Fills the KV/SSM cache and returns last-position
    logits (the serving prefill).
    """

    def prefill(params, cache, tokens, aux_inputs=None):
        B, T = tokens.shape
        pos = M.default_positions(B, T)
        logits, cache, _, _ = M.forward(cfg, params, tokens, pos, cache=cache,
                                        aux_inputs=aux_inputs)
        return logits[:, -1], cache

    return prefill


def make_decode_step(cfg, *, window: int = 0):
    """(params, cache, token (B,1), pos (B,1)) -> (logits (B,V), cache).

    One auto-regressive step against the cache; ``window`` > 0 selects
    sliding-window attention over a circular cache (long-context decode).
    """

    def decode(params, cache, token, pos):
        logits, cache, _, _ = M.forward(cfg, params, token, pos, cache=cache,
                                        window=window)
        return logits[:, -1], cache

    return decode


def make_verify_step(cfg, *, window: int = 0):
    """The paper's partial prefill (§4.5): a chunk of `uncached accepted
    tokens + pending-verify draft tokens` is forwarded over a KV-cached
    prefix.  Returns per-position logits for the verifier.

    tokens: (B, C) chunk; pos: (B, C) absolute positions (contiguous,
    starting at each request's cached length).

    This is the *legacy/debug* step: it hands the full (B, C, V) logits
    to the host.  The serving hot path uses
    :func:`make_cloud_verify_step`, whose fused epilogue keeps the
    full-vocab tensor device-resident.
    """

    def verify(params, cache, tokens, pos):
        logits, cache, _, _ = M.forward(cfg, params, tokens, pos, cache=cache,
                                        window=window)
        return logits, cache

    return verify


def fused_verify_epilogue(logits, targets, sel_idx, top_k: int,
                          with_dists: bool = True):
    """Device-resident verification epilogue (the hot-path contract).

    logits: (B, C, V); targets: (B, C) int32 token ids whose probability
    the verifier needs (-1 = no target, e.g. the bonus row);
    sel_idx: (B, R) int32 local row indices of the rows the verifier
    will actually consume (the last gamma+1 rows of each request; -1 =
    unused).  R << C, so every vocab-sized reduction touches only the
    selected rows — the chunk's full (B, C, V) logits are consumed by
    nothing but the row gather.

    Returns ``(token_id, p_target, topk_idx, topk_val)`` — the only
    verification state that ever crosses to the host:

    * ``token_id`` (B, R)   -- selected rows' argmax (greedy verification)
    * ``p_target`` (B, R)   -- softmax probability of the selected rows'
      targets (the stochastic accept test of Leviathan verification),
      exact via logsumexp — no full softmax is materialized
    * ``topk_idx/val`` (B, R, K) -- top-k of the selected rows' softmax:
      the cloud's sampling support, used for the rejection-resample
      residual and the bonus token.  Exact when top_k >= vocab;
      otherwise the cloud samples top-k (the same support-compression
      argument as the §4.2 uplink).

    Greedy verification consumes only ``token_id``; pass
    ``with_dists=False`` to skip the probability work entirely (the
    p/top-k outputs come back as zeros) — the scheduler selects the
    variant per iteration from the batched requests' sampling modes.
    """
    B = logits.shape[0]
    R = sel_idx.shape[1]
    lf = logits.astype(jnp.float32)
    selc = jnp.clip(sel_idx, 0, lf.shape[1] - 1).astype(jnp.int32)
    rows = jnp.take_along_axis(lf, selc[..., None], axis=1)       # (B, R, V)
    token_id = jnp.argmax(rows, axis=-1).astype(jnp.int32)        # (B, R)
    if not with_dists:
        return (token_id, jnp.zeros((B, R), jnp.float32),
                jnp.zeros((B, R, top_k), jnp.int32),
                jnp.zeros((B, R, top_k), jnp.float32))
    tsel = jnp.take_along_axis(targets, selc, axis=1)             # (B, R)
    lse = jax.scipy.special.logsumexp(rows, axis=-1)              # (B, R)
    tgt = jnp.clip(tsel, 0, lf.shape[-1] - 1).astype(jnp.int32)
    p_t = jnp.exp(jnp.take_along_axis(rows, tgt[..., None], axis=-1)[..., 0]
                  - lse)
    p_t = jnp.where((tsel >= 0) & (sel_idx >= 0), p_t, 0.0)
    # top-k on logits == top-k on probs (softmax is monotone)
    tkl, topk_idx = jax.lax.top_k(rows, top_k)
    topk_val = jnp.exp(tkl - lse[..., None])
    return token_id, p_t, topk_idx.astype(jnp.int32), topk_val


def make_cloud_verify_step(cfg, *, window: int = 0, top_k: int = 8,
                           with_dists: bool = True):
    """Fused serving step: partial-prefill forward + on-device
    verification epilogue + last-valid-row gather.

    (params, cache, tokens (B,C), pos (B,C), targets (B,C),
     sel_idx (B,R), last_local (B,)) ->
        ((token_id (B,R), p_target (B,R), topk_idx (B,R,K),
          topk_val (B,R,K), last_row (B,V)), cache)

    ``with_dists=False`` compiles the greedy-only variant (argmax rows,
    no probability work).  ``last_local`` indexes each slot's last valid
    row within the chunk; the gathered full-vocab row backs prefill
    completions (the sampling verifier's pre-draft row) — callers only
    fetch it on prefill iterations, so verify iterations never move a
    vocab-sized tensor to the host.
    """

    def step(params, cache, tokens, pos, targets, sel_idx, last_local):
        logits, cache, _, _ = M.forward(cfg, params, tokens, pos, cache=cache,
                                        window=window)
        tok, p_t, tk_i, tk_v = fused_verify_epilogue(
            logits, targets, sel_idx, top_k, with_dists=with_dists)
        last = jnp.take_along_axis(
            logits, last_local[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return (tok, p_t, tk_i, tk_v, last.astype(jnp.float32)), cache

    return step


def make_cloud_decode_step(cfg, *, window: int = 0, top_k: int = 8):
    """Fused decode step: one token per slot, returns only the argmax id
    and the top-k sampling support (never the (B, V) logits).

    (params, cache, token (B,1), pos (B,1)) ->
        ((token_id (B,), topk_idx (B,K), topk_val (B,K)), cache)
    """

    def step(params, cache, token, pos):
        logits, cache, _, _ = M.forward(cfg, params, token, pos, cache=cache,
                                        window=window)
        row = logits[:, -1].astype(jnp.float32)
        tok = jnp.argmax(row, axis=-1).astype(jnp.int32)
        # top-k on logits == top-k on probs; normalize the K kept values
        # via logsumexp instead of materializing the full softmax
        tkl, tk_i = jax.lax.top_k(row, top_k)
        lse = jax.scipy.special.logsumexp(row, axis=-1)
        tk_v = jnp.exp(tkl - lse[..., None])
        return (tok, tk_i.astype(jnp.int32), tk_v), cache

    return step


def make_device_draft_step(cfg):
    """Device-side SLM forward for a draft chunk: returns logits,
    updated cache, and the paper's importance scores (column sums of the
    attention matrix over the cache).  Importance requires materializing
    the matrix, so the implementation is either the naive path or the
    fused attn_importance Pallas kernel (``attn_impl="pallas"``)."""
    dev_cfg = cfg if cfg.attn_impl == "pallas" else cfg.replace(
        attn_impl="naive")

    def draft(params, cache, tokens, pos):
        logits, cache, imp, _ = M.forward(dev_cfg, params, tokens, pos,
                                          cache=cache, return_importance=True)
        return logits, cache, imp

    return draft
