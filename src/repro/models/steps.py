"""Step factories: the jit-able entry points used by the launcher, the
serving engine, and the multi-pod dry-run.

Every factory closes over the static config and returns a pure function
of (params, state/batch) suitable for ``jax.jit(..., in_shardings=...)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M


def make_train_step(cfg, optimizer, *, micro_batches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    micro_batches > 1 enables in-step gradient accumulation (§Perf
    iteration 7): the global batch is scanned in micro-batches so live
    activations shrink by the accumulation factor (94-layer 235B MoE at
    1M tokens needs ~147 GiB/device of activations without it; v5e HBM
    is 16 GiB).  Semantics are identical up to f32 grad-mean order.
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if micro_batches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbatch = jax.tree.map(
                lambda x: x.reshape((micro_batches,
                                     x.shape[0] // micro_batches)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (loss_i, metrics_i), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, (loss_i, metrics_i)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(body, acc0, mbatch)
            grads = jax.tree.map(lambda g: g / micro_batches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricses)
        params, opt_state, opt_m = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_m)
        return params, opt_state, metrics

    return train_step


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        loss, metrics = M.lm_loss(cfg, params, batch)
        return loss, metrics
    return loss_fn


def make_prefill_step(cfg):
    """(params, cache, tokens, aux) -> (logits_last, cache).

    tokens: (B, T).  Fills the KV/SSM cache and returns last-position
    logits (the serving prefill).
    """

    def prefill(params, cache, tokens, aux_inputs=None):
        B, T = tokens.shape
        pos = M.default_positions(B, T)
        logits, cache, _, _ = M.forward(cfg, params, tokens, pos, cache=cache,
                                        aux_inputs=aux_inputs)
        return logits[:, -1], cache

    return prefill


def make_decode_step(cfg, *, window: int = 0):
    """(params, cache, token (B,1), pos (B,1)) -> (logits (B,V), cache).

    One auto-regressive step against the cache; ``window`` > 0 selects
    sliding-window attention over a circular cache (long-context decode).
    """

    def decode(params, cache, token, pos):
        logits, cache, _, _ = M.forward(cfg, params, token, pos, cache=cache,
                                        window=window)
        return logits[:, -1], cache

    return decode


def make_verify_step(cfg, *, window: int = 0):
    """The paper's partial prefill (§4.5): a chunk of `uncached accepted
    tokens + pending-verify draft tokens` is forwarded over a KV-cached
    prefix.  Returns per-position logits for the verifier.

    tokens: (B, C) chunk; pos: (B, C) absolute positions (contiguous,
    starting at each request's cached length).
    """

    def verify(params, cache, tokens, pos):
        logits, cache, _, _ = M.forward(cfg, params, tokens, pos, cache=cache,
                                        window=window)
        return logits, cache

    return verify


def make_device_draft_step(cfg):
    """Device-side SLM forward for a draft chunk: returns logits,
    updated cache, and the paper's importance scores (column sums of the
    attention matrix over the cache).  Uses the naive attention path
    because importance requires materializing the matrix (or the fused
    Pallas kernel on TPU)."""
    dev_cfg = cfg.replace(attn_impl="naive")

    def draft(params, cache, tokens, pos):
        logits, cache, imp, _ = M.forward(dev_cfg, params, tokens, pos,
                                          cache=cache, return_importance=True)
        return logits, cache, imp

    return draft
