"""Optional sharding hints for model internals.

The model code is mesh-agnostic; launchers (dryrun/train/serve) install
NamedSharding hints here and ``constrain`` applies
``with_sharding_constraint`` where XLA's propagation is known to go wrong
(e.g. the (B, T, V) logits matmul replicating across the model axis —
a measured 4.5x per-device FLOP inflation, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib

import jax

_HINTS: dict = {}


def set_hints(**hints):
    _HINTS.update({k: v for k, v in hints.items() if v is not None})


def clear_hints():
    _HINTS.clear()


@contextlib.contextmanager
def hints(**kw):
    old = dict(_HINTS)
    set_hints(**kw)
    try:
        yield
    finally:
        _HINTS.clear()
        _HINTS.update(old)


def constrain(x, name: str):
    h = _HINTS.get(name)
    if h is None:
        return x
    return jax.lax.with_sharding_constraint(x, h)


def get(name: str):
    return _HINTS.get(name)


def constrain_batch_dim(x, dim: int):
    """Constrain axis ``dim`` of x to the batch axes and everything else
    replicated.  Used inside the blocked-attention KV scan: without it
    XLA shards the scan (block) axis itself across devices, then pays an
    'involuntary full rematerialization' per slice and replicates the
    whole attention computation (measured 16x FLOP inflation)."""
    from jax.sharding import NamedSharding, PartitionSpec
    h = _HINTS.get("mesh_batch_axes")
    if h is None:
        return x
    mesh, axes = h
    total = 1
    for a in axes:
        total *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if x.shape[dim] % total != 0 or x.shape[dim] < total:
        return x
    spec = [None] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))
