"""Synthetic language task with an *exact* ground-truth process.

The container has no datasets, so the paper's quality studies (Table 4,
Fig 5/11/14) are reproduced on a synthetic language whose conditional
distribution p*(x_t | history) is known in closed form:

* a regime-switching order-1 Markov chain (regime chosen by HEADER
  tokens, each regime has its own sparse bigram table), plus
* a deterministic long-range COPY rule: at every position with
  t % copy_every == 0 (t > copy_back), the correct token is the token
  copy_back steps earlier.

The copy rule requires carrying information across many steps — deeper /
wider models learn it markedly better than tiny ones, reproducing the
paper's SLM-vs-LLM capability gap (Table 3) at laptop scale.  Quality
metrics:
  * nll  — negative log-likelihood of generated text under p* (lower
           better; analogue of Rouge/BERTScore continuous quality)
  * copy_acc — accuracy on the deterministic copy positions (the
           "task accuracy" analogue, cf. CSQA/SST2 accuracy)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TaskSpec:
    vocab: int = 64           # includes regime HEADER tokens at the top
    n_regimes: int = 4
    branching: int = 4        # successors per token per regime
    copy_every: int = 16
    copy_back: int = 8
    regime_len: int = 64      # tokens between regime switches
    seed: int = 1234

    @property
    def base_vocab(self) -> int:
        return self.vocab - self.n_regimes

    def header(self, r: int) -> int:
        return self.base_vocab + r


class SyntheticTask:
    def __init__(self, spec: TaskSpec = TaskSpec()):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        V, R, B = spec.base_vocab, spec.n_regimes, spec.branching
        # sparse bigram tables: for each regime and token, `branching`
        # allowed successors with Dirichlet weights
        self.succ = rng.integers(0, V, size=(R, V, B))
        w = rng.dirichlet(np.ones(B) * 0.6, size=(R, V))
        self.succ_p = w  # (R, V, B)

    # ------------------------------------------------------------------
    def _regime_at(self, t: int, regime_seq: np.ndarray) -> int:
        return int(regime_seq[t // self.spec.regime_len])

    def true_dist(self, history: np.ndarray, t: int,
                  regime_seq: np.ndarray) -> np.ndarray:
        """p*(x_t | history). history: tokens x_0..x_{t-1}."""
        sp = self.spec
        V = sp.vocab
        p = np.zeros(V)
        if t % sp.regime_len == 0:
            p[sp.header(self._regime_at(t, regime_seq))] = 1.0
            return p
        if t % sp.copy_every == 0 and t >= sp.copy_back:
            p[int(history[t - sp.copy_back])] = 1.0
            return p
        r = self._regime_at(t, regime_seq)
        prev = int(history[t - 1])
        if prev >= sp.base_vocab:  # after a header: uniform over successors
            prev = 0
        # np.add.at: duplicate successors must accumulate
        np.add.at(p, self.succ[r, prev], self.succ_p[r, prev])
        return p

    def sample_sequence(self, length: int, rng: np.random.Generator):
        sp = self.spec
        n_blocks = length // sp.regime_len + 2
        regime_seq = rng.integers(0, sp.n_regimes, size=n_blocks)
        x = np.zeros(length, np.int64)
        for t in range(length):
            p = self.true_dist(x, t, regime_seq)
            x[t] = rng.choice(sp.vocab, p=p)
        return x, regime_seq

    def corpus(self, n_sequences: int, length: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        seqs, regimes = [], []
        for _ in range(n_sequences):
            x, r = self.sample_sequence(length, rng)
            seqs.append(x)
            regimes.append(r)
        return np.stack(seqs), regimes

    # ------------------------------------------------------------------
    # Quality metrics
    # ------------------------------------------------------------------
    def score(self, full_seq: np.ndarray, regime_seq: np.ndarray,
              start: int, nll_cap: float = 6.0) -> dict:
        """Score tokens full_seq[start:] generated as a continuation of
        full_seq[:start] under the true process.

        Token NLL is capped (impossible tokens would otherwise dominate
        the mean with -log(1e-12) spikes and make ``quality`` a coin
        flip on a single bad token — the cap makes it a robust
        Rouge/BERTScore-like continuous score in (e^-cap, 1]).
        """
        sp = self.spec
        nlls, copy_hits, copy_total, valid = [], 0, 0, 0
        for t in range(start, len(full_seq)):
            p = self.true_dist(full_seq, t, regime_seq)
            q = float(p[int(full_seq[t])])
            valid += int(q > 0)
            nlls.append(min(-np.log(max(q, 1e-12)), nll_cap))
            if t % sp.copy_every == 0 and t >= sp.copy_back \
                    and t % sp.regime_len != 0:
                copy_total += 1
                copy_hits += int(full_seq[t] == full_seq[t - sp.copy_back])
        return {
            "nll": float(np.mean(nlls)) if nlls else 0.0,
            "copy_acc": copy_hits / max(copy_total, 1),
            "quality": float(np.exp(-np.mean(nlls))) if nlls else 0.0,
            "valid_frac": valid / max(len(nlls), 1),
        }


def batches(corpus: np.ndarray, batch_size: int, seq_len: int, *,
            rng: np.random.Generator):
    """Infinite iterator of LM training batches from a corpus of
    (n_sequences, length) token arrays."""
    n, length = corpus.shape
    while True:
        rows = rng.integers(0, n, size=batch_size)
        starts = rng.integers(0, length - seq_len, size=batch_size)
        yield np.stack([corpus[r, s:s + seq_len]
                        for r, s in zip(rows, starts)])
